//! `neuromax` CLI — the coordinator's front door.
//!
//! ```text
//! report <id|all>        regenerate a paper table/figure
//! simulate <network>     per-layer cycle simulation of a CNN
//! infer [opts]           run zoo-model inferences (PJRT or sim backend)
//! verify [opts]          sim-vs-HLO bit-exactness check
//! serve [opts]           TCP inference server (whole zoo, sharded pool)
//! loadgen [opts]         closed-loop load generator -> BENCH_serve.json
//! sweep                  design-space exploration (grid geometry)
//! trace [opts]           §5.1 pipeline waveform
//! ```
//!
//! Operator documentation: `README.md` §"Operating the server" and
//! `docs/PROTOCOL.md` (wire protocol).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use neuromax::arch::config::GridConfig;
use neuromax::coordinator::batcher::BatchPolicy;
use neuromax::coordinator::health::HealthState;
use neuromax::coordinator::metrics::parse_model_gauge;
use neuromax::coordinator::pipeline::{Backend, InferenceEngine};
use neuromax::coordinator::replicate::{RecalPolicy, ReplicationPolicy};
use neuromax::coordinator::reports;
use neuromax::coordinator::shard::PoolOptions;
use neuromax::coordinator::server::{busy_backoff_us, Client, Reply, Server};
use neuromax::coordinator::NetworkSchedule;
use neuromax::dataflow::engine::resolve_threads;
use neuromax::dataflow::{cached_program, explain_rows, EngineOptions, ScheduleOptions};
use neuromax::models::workload;
use neuromax::runtime::{verify, Runtime};
use neuromax::sim::stats::simulate_network;
use neuromax::util::bench::{BenchLog, Measurement};
use neuromax::util::prng::SplitMix64;
use neuromax::util::table;

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("report") => cmd_report(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("infer") => cmd_infer(&args[1..]),
        Some("verify") => cmd_verify(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("loadgen") => cmd_loadgen(&args[1..]),
        Some("explain") => cmd_explain(&args[1..]),
        Some("calibrate") => cmd_calibrate(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        _ => {
            eprintln!(
                "usage: neuromax <subcommand> ...   (report | simulate | infer | verify\n\
                 \x20        | serve | loadgen | explain | calibrate | sweep | trace)\n\
                 \n\
                 report  <fig1|fig17|table1|fig18|fig19|fig20|table2|table3|sec5|all>\n\
                 simulate <model> [--packing]\n\
                 infer   [--model NAME] [--backend hlo|sim] [--count N] [--seed S]\n\
                         [--threads N]   (hlo backend serves tinycnn only)\n\
                 verify  [--cases N] [--seed S] [--model NAME] [--threads N]\n\
                 serve   [--model NAME] [--addr HOST:PORT] [--backend hlo|sim]\n\
                         [--secs N] [--batch N] [--wait-ms N] [--queue-cap N]\n\
                         [--threads N (0 = one per core)]\n\
                         [--spill-threshold N (route off the home shard when\n\
                          its queue is this deep; default: batch size)]\n\
                         [--adaptive (hot-model replication + online cost\n\
                          recalibration — see docs/PROTOCOL.md)]\n\
                         [--cost-table PATH (measured SwCost constants from\n\
                          `neuromax calibrate` — installed before any plan)]\n\
                         [--shards N (0 = auto: cores / engine threads)]\n\
                         [--chaos SPEC e.g. seed=1,panic=10,slow=5,slow_us=2000\n\
                          — or set NEUROMAX_CHAOS; see docs/PROTOCOL.md]\n\
                 loadgen [--shards LIST e.g. 1,2,4] [--conns N] [--requests N]\n\
                         [--mix name:w,name:w | hotspot | diurnal]\n\
                         [--batch N] [--wait-ms N]\n\
                         [--queue-cap N] [--threads N] [--out PATH]\n\
                         (each shard count runs twice — static affinity pool\n\
                          vs adaptive replicated pool -> BENCH_serve.json)\n\
                         [--chaos  (deterministic fault-injection harness:\n\
                          2 shards, injected panics/slow-chunks/torn replies,\n\
                          quarantine + recovery check -> BENCH_faults.json)]\n\
                         [--chaos-spec SPEC  (override the harness fault mix)]\n\
                 explain [MODEL | --model NAME] [--threads N (0 = one per core)]\n\
                         [--cost-table PATH]\n\
                         (compiled step-plan table: kernel, split, chunks,\n\
                          predicted hw/sw utilization — Fig. 19's software twin;\n\
                          live servers answer the same table to `EXPLAIN <model>`)\n\
                 calibrate [--out PATH (default BENCH_calibrate.json)] [--runs N]\n\
                         (micro-benchmark the row kernels and every arch GEMM\n\
                          micro-kernel on this machine; the JSON it writes is\n\
                          what serve/explain `--cost-table` loads)\n\
                 sweep\n\
                 trace   [--stride 1|2] [--cycles N]   (§5.1 pipeline waveform)\n\
                 \n\
                 <model>/NAME: tinycnn | alexnet | vgg16 | resnet34 | mobilenet_v1\n\
                   | squeezenet — or any `<name>-test` scaled profile; the server\n\
                   protocol additionally accepts `INFER <model> <seed>` per request"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_trace(args: &[String]) -> Result<()> {
    use neuromax::tensor::{Tensor3, Tensor4};
    let stride: usize = opt(args, "--stride").and_then(|v| v.parse().ok()).unwrap_or(1);
    let max: usize = opt(args, "--cycles").and_then(|v| v.parse().ok()).unwrap_or(16);
    let mut rng = SplitMix64::new(1);
    let mut a = Tensor3::new(12, 6, 1);
    for v in a.data.iter_mut() {
        *v = rng.range_i32(-6, 4);
    }
    let mut wc = Tensor4::new(1, 3, 3, 1);
    let mut ws = Tensor4::new(1, 3, 3, 1);
    for v in wc.data.iter_mut() {
        *v = rng.range_i32(-4, 4);
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }
    print!(
        "{}",
        neuromax::sim::trace::trace_conv3x3(&a, &wc, &ws, stride, max)
    );
    Ok(())
}

fn cmd_report(args: &[String]) -> Result<()> {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let out = match which {
        "fig1" => reports::fig1(),
        "fig17" => reports::fig17(),
        "table1" => reports::table1(),
        "fig18" => reports::fig18(),
        "fig19" => reports::fig19(),
        "fig20" => reports::fig20(),
        "table2" => reports::table2(),
        "table3" => reports::table3(),
        "sec5" => reports::sec5(),
        "all" => reports::all(),
        other => bail!("unknown report `{other}`"),
    };
    println!("{out}");
    Ok(())
}

fn cmd_simulate(args: &[String]) -> Result<()> {
    let name = args.first().context("simulate: network name required")?;
    let net = workload::by_name(name).with_context(|| format!("unknown network `{name}`"))?;
    let grid = GridConfig::neuromax();
    let optn = ScheduleOptions { filter_packing: flag(args, "--packing"), ..Default::default() };
    let rep = simulate_network(&grid, &net, optn);
    let mut rows = vec![vec![
        "layer".into(), "cycles".into(), "MACs".into(), "util%".into(),
        "lat(ms)".into(), "GOPS".into(), "DDR(Mb)".into(),
    ]];
    for lr in &rep.layers {
        rows.push(vec![
            lr.perf.name.clone(),
            table::count(lr.perf.cycles),
            table::count(lr.perf.macs),
            table::f(100.0 * lr.util_total, 1),
            table::f(lr.latency_ms, 2),
            table::f(lr.gops_paper, 1),
            table::f(lr.perf.traffic.ddr_total_bits() as f64 / 1e6, 2),
        ]);
    }
    println!("{}", table::render(&rows));
    println!(
        "{}: {} cycles, {:.2} ms/frame ({:.1} fps), avg util {:.1}%, \
         {:.1} GOPS (paper accounting), {:.1} GOPS physical",
        rep.name,
        table::count(rep.total_cycles),
        rep.total_latency_ms,
        1000.0 / rep.total_latency_ms,
        100.0 * rep.avg_util,
        rep.gops_paper,
        rep.gops_physical
    );
    let sched = NetworkSchedule::plan(grid, &net, optn);
    println!(
        "DDR traffic/frame: {:.1} Mb; layers streaming (fmap > input SRAM): {}",
        sched.total_ddr_bits() as f64 / 1e6,
        sched.plans.iter().filter(|p| !p.input_resident).count()
    );
    Ok(())
}

fn cmd_infer(args: &[String]) -> Result<()> {
    let backend = match opt(args, "--backend").as_deref() {
        Some("sim") => Backend::Sim,
        _ => Backend::Hlo,
    };
    let model = opt(args, "--model").unwrap_or_else(|| "tinycnn".into());
    let count: usize = opt(args, "--count").and_then(|v| v.parse().ok()).unwrap_or(16);
    let seed: u64 = opt(args, "--seed").and_then(|v| v.parse().ok()).unwrap_or(1);
    let threads: usize = opt(args, "--threads").and_then(|v| v.parse().ok()).unwrap_or(0);
    let mut engine = InferenceEngine::for_model(
        &model,
        backend,
        7,
        EngineOptions { num_threads: threads, ..Default::default() },
    )?;
    engine.warmup()?;
    let t0 = Instant::now();
    let mut classes: std::collections::HashMap<usize, usize> = Default::default();
    for i in 0..count {
        let input = engine.input(seed + i as u64);
        let inf = engine.infer(&input)?;
        *classes.entry(inf.class).or_default() += 1;
        if i < 4 {
            println!(
                "req {i}: class {} wall {} us (accel: {} cycles = {:.1} us at 200 MHz)",
                inf.class, inf.wall_us, inf.accel_cycles,
                inf.accel_cycles as f64 / 200.0
            );
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let mut top: Vec<(usize, usize)> = classes.into_iter().collect();
    top.sort_by_key(|&(_, n)| std::cmp::Reverse(n));
    top.truncate(8);
    println!(
        "{count} inferences of {} ({backend:?}) in {:.3} s = {:.1} req/s; \
         top (class, hits): {top:?}",
        engine.model.name,
        dt,
        count as f64 / dt
    );
    Ok(())
}

fn cmd_verify(args: &[String]) -> Result<()> {
    let cases: usize = opt(args, "--cases").and_then(|v| v.parse().ok()).unwrap_or(8);
    let seed: u64 = opt(args, "--seed").and_then(|v| v.parse().ok()).unwrap_or(42);
    if let Some(model) = opt(args, "--model") {
        // PJRT-free path: reference executor vs LUT-fused engine over a
        // zoo model (use the `-test` profiles for quick runs)
        let threads: usize =
            opt(args, "--threads").and_then(|v| v.parse().ok()).unwrap_or(4);
        let net = workload::by_name(&model)
            .with_context(|| format!("unknown network `{model}`"))?;
        let r = verify::verify_zoo_model(&net, cases, seed, threads)?;
        println!(
            "{} ref-exec vs engine ({threads} threads) over {} cases: \
             {} elements, {} mismatches",
            net.name, r.cases, r.elements_compared, r.mismatches
        );
        anyhow::ensure!(r.ok(), "zoo verification FAILED");
        println!("VERIFY OK — reference and engine agree bit-for-bit");
        return Ok(());
    }
    let mut rt = Runtime::from_default_dir()?;
    println!("platform: {}", rt.platform());
    let r = verify::verify_conv3x3(&mut rt, seed)?;
    println!(
        "conv3x3 HLO vs fast-sim vs faithful-core: {} elements, {} mismatches",
        r.elements_compared, r.mismatches
    );
    anyhow::ensure!(r.ok(), "conv3x3 verification FAILED");
    let r = verify::verify_tinycnn(&mut rt, cases, seed)?;
    println!(
        "tinycnn HLO vs sim over {} cases: {} logits, {} mismatches",
        r.cases, r.elements_compared, r.mismatches
    );
    anyhow::ensure!(r.ok(), "tinycnn verification FAILED");
    println!("VERIFY OK — simulator and AOT executable agree bit-for-bit");
    Ok(())
}

/// Shared `--cost-table PATH` handling: load a `neuromax calibrate` JSON
/// table and install its measured constants as the process-wide software
/// cost model. Must run before the first plan is compiled (plans are
/// cached per process); first install wins, later ones warn.
fn install_cost_table(args: &[String]) -> Result<()> {
    if let Some(path) = opt(args, "--cost-table") {
        let json = std::fs::read_to_string(&path)
            .with_context(|| format!("--cost-table: reading {path}"))?;
        let o = neuromax::dataflow::CostOverride::from_json(&json)
            .map_err(|e| anyhow::anyhow!("--cost-table {path}: {e}"))?;
        if neuromax::dataflow::install_cost_override(o) {
            println!("cost table: installed {path}");
        } else {
            eprintln!("cost table: an override is already installed; {path} ignored");
        }
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    install_cost_table(args)?;
    let addr = opt(args, "--addr").unwrap_or_else(|| "127.0.0.1:7878".into());
    let backend = match opt(args, "--backend").as_deref() {
        Some("hlo") => Backend::Hlo,
        _ => Backend::Sim,
    };
    let model = opt(args, "--model").unwrap_or_else(|| "tinycnn".into());
    let secs: u64 = opt(args, "--secs").and_then(|v| v.parse().ok()).unwrap_or(30);
    let policy = batch_policy_from_args(args);
    let threads: usize = opt(args, "--threads").and_then(|v| v.parse().ok()).unwrap_or(0);
    // 0 = auto-size the pool (available cores / engine threads); with the
    // default --threads 0 (one worker per core) that resolves to 1 shard,
    // the classic layout
    let shards: usize = opt(args, "--shards").and_then(|v| v.parse().ok()).unwrap_or(0);
    // deterministic fault injection: `--chaos <spec>` wins, else the
    // NEUROMAX_CHAOS env var; either way injected panics are silenced
    // (they are contained and answered `ERR internal`, not crashes)
    if let Some(raw) = opt(args, "--chaos") {
        let spec = neuromax::util::fault::FaultSpec::parse(&raw)
            .map_err(|e| anyhow::anyhow!("--chaos: {e}"))?;
        neuromax::util::fault::silence_injected_panics();
        neuromax::util::fault::install(spec);
        println!("chaos: {spec:?}");
    } else if let Some(plan) = neuromax::util::fault::install_from_env() {
        neuromax::util::fault::silence_injected_panics();
        println!("chaos (NEUROMAX_CHAOS): {:?}", plan.spec());
    }
    // adaptive pool: --adaptive arms both feedback loops (hot-model
    // replication + online cost recalibration) at their default
    // policies; --spill-threshold overrides the home-queue depth at
    // which jobs route away (default: one full batch)
    let adaptive = flag(args, "--adaptive");
    let pool_opts = PoolOptions {
        spill_threshold: opt(args, "--spill-threshold").and_then(|v| v.parse().ok()),
        replication: adaptive.then(ReplicationPolicy::default),
        recal: adaptive.then(RecalPolicy::default),
        ..Default::default()
    };
    let mut srv = Server::start_sharded_with_opts(
        &addr,
        &model,
        backend,
        policy,
        EngineOptions { num_threads: threads, ..Default::default() },
        shards,
        pool_opts,
    )?;
    println!(
        "serving {model} ({backend:?}) on {} for {secs}s — {} engine shard(s), \
         batch {} / wait {:?} / queue cap {}, pool {}",
        srv.addr,
        srv.shards(),
        policy.max_batch,
        policy.max_wait,
        policy.queue_cap,
        if adaptive { "adaptive (replication + recalibration)" } else { "static affinity" },
    );
    srv.serve_until(Some(Instant::now() + Duration::from_secs(secs)))?;
    let metrics = srv.metrics.clone();
    srv.shutdown();
    // after shutdown: the drained requests' replies are in the counters
    println!("{}", metrics.summary());
    Ok(())
}

/// Shared `--batch` / `--wait-ms` / `--queue-cap` parsing for the serving
/// commands.
fn batch_policy_from_args(args: &[String]) -> BatchPolicy {
    let d = BatchPolicy::default();
    BatchPolicy {
        max_batch: opt(args, "--batch").and_then(|v| v.parse().ok()).unwrap_or(d.max_batch),
        max_wait: Duration::from_millis(
            opt(args, "--wait-ms").and_then(|v| v.parse().ok()).unwrap_or(2),
        ),
        queue_cap: opt(args, "--queue-cap")
            .and_then(|v| v.parse().ok())
            .unwrap_or(d.queue_cap),
    }
}

/// One completed loadgen run against a fresh in-process server.
struct LoadgenRun {
    completed: usize,
    busy_retries: u64,
    elapsed: Duration,
    p50_us: u64,
    p99_us: u64,
    /// Largest per-model activation-arena high-water mark, bytes.
    arena_peak_bytes: u64,
    /// Total arena grow events across all models (warmup only; a warmed
    /// server adds none per request).
    arena_allocs: u64,
    /// Jobs routed away from their home shard.
    spills: u64,
    /// Jobs that landed on a live replica of their model (a subset of
    /// off-home routing that keeps warm state, unlike a cold spill).
    replica_hits: u64,
    /// Replication-controller grow / shrink actions taken.
    replica_grows: u64,
    replica_shrinks: u64,
    /// Measured per-model engine utilization, parsed back out of the
    /// `STATS` wire line (`util_pct`), in `--mix` order.
    model_utils: Vec<(String, f64)>,
}

/// Closed-loop load generator: `conns` connections each send their share
/// of `total` requests back-to-back (a new request only after the
/// previous reply), drawing models from the weighted `mix`. `BUSY`
/// replies back off and retry, so every request eventually completes.
/// When `late_mix` is set (the diurnal preset), each connection switches
/// to those weights for the second half of its quota — a deterministic
/// phase shift of the hot model. `opts` selects the pool flavor: static
/// affinity ([`PoolOptions::default`]) or the adaptive replicated pool.
#[allow(clippy::too_many_arguments)]
fn drive_loadgen(
    shards: usize,
    conns: usize,
    total: usize,
    mix: &[(String, u64)],
    late_mix: Option<&[(String, u64)]>,
    policy: BatchPolicy,
    eopt: EngineOptions,
    opts: PoolOptions,
) -> Result<LoadgenRun> {
    let mut srv = Server::start_sharded_with_opts(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        policy,
        eopt,
        shards,
        opts,
    )?;
    let addr = srv.addr;
    let busy = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..conns)
        .map(|c| {
            let n = total / conns + usize::from(c < total % conns);
            let busy = busy.clone();
            let mix = mix.to_vec();
            let late = late_mix.map(<[(String, u64)]>::to_vec);
            thread::spawn(move || -> Result<Vec<u64>> {
                let mut rng =
                    SplitMix64::new(0xC0FFEE ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let mut cl = Client::connect(addr)?;
                let mut lats = Vec::with_capacity(n);
                for i in 0..n {
                    // diurnal phase shift: the late mix takes over for
                    // the second half of this connection's quota
                    let phase: &[(String, u64)] = match &late {
                        Some(l) if 2 * i >= n => l,
                        _ => &mix,
                    };
                    let weight_sum: u64 = phase.iter().map(|(_, w)| *w).sum();
                    let mut t = rng.below(weight_sum.max(1));
                    let mut model = phase.last().map(|(m, _)| m.as_str());
                    for (m, w) in phase {
                        if t < *w {
                            model = Some(m.as_str());
                            break;
                        }
                        t -= w;
                    }
                    let seed = (c * 100_000 + i) as u64;
                    // BUSY backoff: jittered exponential (seeded — runs are
                    // reproducible), reset once a request gets through, so
                    // a burst of refusals doesn't turn into lockstep retry
                    // storms at a fixed period
                    let mut attempt = 0u32;
                    loop {
                        match cl.request(model, seed)? {
                            Reply::Ok { latency_us, .. } => {
                                lats.push(latency_us);
                                break;
                            }
                            Reply::Busy(_) => {
                                busy.fetch_add(1, Ordering::Relaxed);
                                thread::sleep(Duration::from_micros(busy_backoff_us(
                                    attempt, &mut rng,
                                )));
                                attempt += 1;
                            }
                            Reply::Err(e) => bail!("loadgen request failed: {e}"),
                        }
                    }
                }
                Ok(lats)
            })
        })
        .collect();
    // is_finished (not a success counter): a connection that errors out
    // must end the loop too, not stall until the hard deadline
    srv.serve_while(Duration::from_secs(600), || {
        handles.iter().all(|h| h.is_finished())
    })?;
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap()?);
    }
    let elapsed = t0.elapsed();
    // arena gauges before teardown: peak footprint + total grow events
    let (mut arena_peak_bytes, mut arena_allocs) = (0u64, 0u64);
    for ms in srv.metrics.models.lock().unwrap().values() {
        arena_peak_bytes =
            arena_peak_bytes.max(ms.arena_peak_bytes.load(Ordering::Relaxed));
        arena_allocs += ms.arena_allocs.load(Ordering::Relaxed);
    }
    // per-model utilization: pull util_pct back out of the STATS wire
    // line, so the JSON trail exercises what clients actually see
    // (late-mix models appended so diurnal runs report both phases)
    let summary = srv.metrics.summary();
    let mut names: Vec<&String> = mix.iter().map(|(m, _)| m).collect();
    for (m, _) in late_mix.unwrap_or_default() {
        if !names.contains(&m) {
            names.push(m);
        }
    }
    let model_utils: Vec<(String, f64)> = names
        .into_iter()
        .map(|m| (m.clone(), parse_model_gauge(&summary, m, "util_pct").unwrap_or(0.0)))
        .collect();
    let spills = srv.metrics.spills.load(Ordering::Relaxed);
    let replica_hits = srv.metrics.replica_hits.load(Ordering::Relaxed);
    let replica_grows = srv.metrics.replica_grows.load(Ordering::Relaxed);
    let replica_shrinks = srv.metrics.replica_shrinks.load(Ordering::Relaxed);
    srv.shutdown();
    all.sort_unstable();
    anyhow::ensure!(!all.is_empty(), "loadgen completed zero requests");
    let n = all.len();
    Ok(LoadgenRun {
        completed: n,
        busy_retries: busy.load(Ordering::Relaxed),
        elapsed,
        p50_us: all[n / 2],
        p99_us: all[(n * 99 / 100).min(n - 1)],
        arena_peak_bytes,
        arena_allocs,
        spills,
        replica_hits,
        replica_grows,
        replica_shrinks,
        model_utils,
    })
}

/// Parse one `name:w,name:w` weighted-mix spec into canonical names.
fn parse_mix(spec: &str) -> Result<Vec<(String, u64)>> {
    let mix: Vec<(String, u64)> = spec
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|pair| {
            let (name, w) = pair.split_once(':').unwrap_or((pair, "1"));
            let canon = workload::canonical_name(name.trim())
                .with_context(|| format!("unknown model `{name}` in --mix"))?;
            let w: u64 = w.trim().parse().map_err(|_| {
                anyhow::anyhow!("bad weight `{w}` for `{name}` in --mix")
            })?;
            Ok((canon, w.max(1)))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!mix.is_empty(), "--mix resolved to no models");
    Ok(mix)
}

fn cmd_loadgen(args: &[String]) -> Result<()> {
    if flag(args, "--chaos") {
        return cmd_loadgen_chaos(args);
    }
    // NEUROMAX_BENCH_QUICK=1 (the CI smoke mode) shrinks the sweep but
    // keeps the replicated-vs-affinity comparison rows intact
    let quick = std::env::var("NEUROMAX_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let shard_counts: Vec<usize> = opt(args, "--shards")
        .unwrap_or_else(|| if quick { "1,2".into() } else { "1,2,4".into() })
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad --shards entry `{s}`"))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(!shard_counts.is_empty(), "--shards list is empty");
    let conns: usize = opt(args, "--conns").and_then(|v| v.parse().ok()).unwrap_or(8).max(1);
    let total: usize = opt(args, "--requests")
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 160 } else { 400 })
        .max(1);
    let mix_spec = opt(args, "--mix")
        .unwrap_or_else(|| "tinycnn:6,squeezenet-test:2,alexnet-test:2".into());
    // named presets: `hotspot` skews hard onto one model (the
    // replication trigger case); `diurnal` flips the hot model halfway
    // through each connection's quota
    let (mix_spec, late_spec) = match mix_spec.as_str() {
        "hotspot" => ("tinycnn:14,alexnet-test:1,squeezenet-test:1".to_string(), None),
        "diurnal" => (
            "tinycnn:8,squeezenet-test:1".to_string(),
            Some("tinycnn:1,squeezenet-test:8".to_string()),
        ),
        _ => (mix_spec, None),
    };
    let mix = parse_mix(&mix_spec)?;
    let late_mix = late_spec.as_deref().map(parse_mix).transpose()?;
    let policy = batch_policy_from_args(args);
    let threads: usize = opt(args, "--threads").and_then(|v| v.parse().ok()).unwrap_or(0);
    let eopt = EngineOptions { num_threads: threads, ..Default::default() };
    let out = opt(args, "--out").unwrap_or_else(|| "BENCH_serve.json".into());

    let mix_label: Vec<String> =
        mix.iter().map(|(m, w)| format!("{m}:{w}")).collect();
    println!(
        "loadgen: closed loop, {conns} connections x {total} total requests, \
         mix [{}], batch {} / wait {:?} / queue cap {}",
        mix_label.join(","),
        policy.max_batch,
        policy.max_wait,
        policy.queue_cap
    );
    // the adaptive pool under comparison: replication tuned to act
    // within a short closed-loop run, plus online cost recalibration
    let adaptive = PoolOptions {
        replication: Some(ReplicationPolicy {
            tick: Duration::from_millis(10),
            window: 2,
            grow_util_pct: 20.0,
            grow_min_arrivals: 4,
            cold_ticks: 20,
            shrink_util_pct: 2.0,
            ..Default::default()
        }),
        recal: Some(RecalPolicy::default()),
        ..Default::default()
    };
    let mut log = BenchLog::new();
    for &s in &shard_counts {
        // every shard count runs twice: the static affinity pool (the
        // legacy row names, so trends stay comparable across PRs) and
        // the adaptive replicated pool — together they are the
        // replicated-vs-affinity scaling curve in BENCH_serve.json
        for (pool, opts) in [("affinity", PoolOptions::default()), ("replicated", adaptive)] {
            let replicated = pool == "replicated";
            let r =
                drive_loadgen(s, conns, total, &mix, late_mix.as_deref(), policy, eopt, opts)?;
            let m =
                Measurement { median: r.elapsed, min: r.elapsed, max: r.elapsed, runs: 1 };
            if replicated {
                log.report(
                    &format!(
                        "serve loadgen replicated shards={s} conns={conns} reqs={}",
                        r.completed
                    ),
                    m,
                    r.completed as u64,
                    "req",
                );
                log.report(&format!("serve replica hits shards={s}"), m, r.replica_hits, "hit");
                log.report(
                    &format!("serve replica grows shards={s}"),
                    m,
                    r.replica_grows,
                    "grow",
                );
                log.report(
                    &format!("serve spills replicated shards={s}"),
                    m,
                    r.spills,
                    "spill",
                );
            } else {
                log.report(
                    &format!("serve loadgen shards={s} conns={conns} reqs={}", r.completed),
                    m,
                    r.completed as u64,
                    "req",
                );
                // arena trail: peak footprint + warmup-only grow events,
                // so the per-request allocation trajectory is trackable
                // across PRs
                log.report(
                    &format!("serve arena peak shards={s}"),
                    m,
                    r.arena_peak_bytes,
                    "B",
                );
                log.report(
                    &format!("serve arena grow events shards={s}"),
                    m,
                    r.arena_allocs,
                    "grow",
                );
                // admission/routing pressure columns + per-model
                // utilization (util_pct from STATS, recorded in basis
                // points: 100 bp = 1%)
                log.report(
                    &format!("serve busy replies shards={s}"),
                    m,
                    r.busy_retries,
                    "busy",
                );
                log.report(&format!("serve spills shards={s}"), m, r.spills, "spill");
                for (model, util) in &r.model_utils {
                    log.report(
                        &format!("serve util_pct {model} shards={s}"),
                        m,
                        (util * 100.0).round() as u64,
                        "bp",
                    );
                }
            }
            let util_label: Vec<String> = r
                .model_utils
                .iter()
                .map(|(model, util)| format!("{model} {util:.1}%"))
                .collect();
            println!(
                "  shards={s} pool={pool}: {} reqs in {:.2}s = {:.0} req/s | \
                 p50 {} us p99 {} us | {} busy retries, {} spills, {} replica hits \
                 ({} grows, {} shrinks) | arena peak {:.1} KiB, {} grow events \
                 ({:.3}/req) | util [{}]",
                r.completed,
                r.elapsed.as_secs_f64(),
                r.completed as f64 / r.elapsed.as_secs_f64(),
                r.p50_us,
                r.p99_us,
                r.busy_retries,
                r.spills,
                r.replica_hits,
                r.replica_grows,
                r.replica_shrinks,
                r.arena_peak_bytes as f64 / 1024.0,
                r.arena_allocs,
                r.arena_allocs as f64 / r.completed.max(1) as f64,
                util_label.join(", "),
            );
        }
    }
    log.write_json(&out)?;
    println!("wrote {out}");
    Ok(())
}

/// What the chaos driver thread measured (see [`cmd_loadgen_chaos`]).
struct ChaosOutcome {
    /// Requests that got *some* terminal outcome: OK, typed ERR, a BUSY
    /// refusal, or a torn connection the client detected. Must equal
    /// the request total — nothing may wedge.
    answered: u64,
    ok: u64,
    /// `ERR internal` / `ERR deadline` replies.
    errs: u64,
    /// `BUSY deadline` / `BUSY no-healthy-shard` refusals.
    busy_refused: u64,
    /// Torn replies: connection dropped mid-`OK`, detected client-side.
    torn_conns: u64,
    p99_us: u64,
    /// Blackout start → first quarantine trip.
    blackout_ms: u64,
    /// Faults cleared → every shard readmitted.
    recovery_ms: u64,
}

/// `loadgen --chaos`: the deterministic fault-injection harness.
///
/// Three phases against a fresh in-process sharded server:
/// 1. clean baseline inferences (no faults armed — also settles warmup);
/// 2. closed-loop traffic under a seeded moderate [`FaultSpec`]
///    (injected chunk panics, slow chunks, arena-grow failures, torn
///    replies) plus an unmeetable deadline on every 7th request — every
///    request must come back answered, and panics must stay contained;
/// 3. blackout (every chunk panics) until a shard quarantines, then
///    faults stop and the supervisor's rebuild + readmission is timed.
///
/// Hard assertions: all requests answered, ≥1 quarantine, recoveries
/// match quarantines, every shard healthy at exit, and a clean
/// `Server::shutdown` (zero wedged threads). Results land in
/// `BENCH_faults.json`.
///
/// [`FaultSpec`]: neuromax::util::fault::FaultSpec
fn cmd_loadgen_chaos(args: &[String]) -> Result<()> {
    use neuromax::util::fault::{self, FaultSpec};

    let shards: usize =
        opt(args, "--shards").and_then(|v| v.parse().ok()).unwrap_or(2).max(1);
    let conns: usize = opt(args, "--conns").and_then(|v| v.parse().ok()).unwrap_or(4).max(1);
    let total: usize =
        opt(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(300).max(conns);
    let spec = match opt(args, "--chaos-spec") {
        Some(raw) => {
            FaultSpec::parse(&raw).map_err(|e| anyhow::anyhow!("--chaos-spec: {e}"))?
        }
        None => FaultSpec {
            seed: 9,
            panic_per_mille: 10,
            slow_per_mille: 5,
            slow_us: 2000,
            grow_per_mille: 2,
            torn_per_mille: 3,
        },
    };
    let out = opt(args, "--out").unwrap_or_else(|| "BENCH_faults.json".into());
    let policy = batch_policy_from_args(args);
    let threads: usize = opt(args, "--threads").and_then(|v| v.parse().ok()).unwrap_or(2);
    let eopt = EngineOptions { num_threads: threads, ..Default::default() };

    fault::silence_injected_panics();
    let mut srv =
        Server::start_sharded("127.0.0.1:0", "tinycnn", Backend::Sim, policy, eopt, shards)?;
    let addr = srv.addr;
    let metrics = srv.metrics.clone();
    println!(
        "chaos loadgen: {} shard(s), {conns} connections x {total} requests, spec {spec:?}",
        srv.shards()
    );
    let t_all = Instant::now();

    let dm = metrics.clone();
    let driver = thread::spawn(move || -> Result<ChaosOutcome> {
        // phase 1: prove the pool clean before any fault is armed (this
        // also finishes warmup, so injection never races construction)
        let mut cl = Client::connect(addr)?;
        for s in 0..4u64 {
            let (class, _) = cl.infer(s)?;
            anyhow::ensure!(class < 10, "clean-baseline inference failed");
        }

        // phase 2: moderate mixed faults under closed-loop traffic
        let plan = fault::install(spec);
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                let n = total / conns + usize::from(c < total % conns);
                thread::spawn(move || -> Result<(u64, u64, u64, u64, Vec<u64>)> {
                    let mut rng = SplitMix64::new(
                        0xFA17 ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut cl = Client::connect(addr)?;
                    let (mut ok, mut errs, mut busy_refused, mut torn) =
                        (0u64, 0u64, 0u64, 0u64);
                    let mut lats = Vec::with_capacity(n);
                    for i in 0..n {
                        let seed = (c * 100_000 + i) as u64;
                        // every 7th request carries an unmeetable zero
                        // deadline — a deterministic `BUSY deadline`
                        let zero_deadline = i % 7 == 3;
                        let mut attempt = 0u32;
                        loop {
                            let reply = if zero_deadline {
                                cl.request_deadline(None, seed, Duration::ZERO)
                            } else {
                                cl.request(None, seed)
                            };
                            match reply {
                                Ok(Reply::Ok { latency_us, .. }) => {
                                    ok += 1;
                                    lats.push(latency_us);
                                    break;
                                }
                                Ok(Reply::Busy(r)) if r == "queue-full" => {
                                    thread::sleep(Duration::from_micros(
                                        busy_backoff_us(attempt, &mut rng),
                                    ));
                                    attempt += 1;
                                }
                                Ok(Reply::Busy(_)) => {
                                    // deadline / no-healthy-shard: refused
                                    // up front — answered, move on
                                    busy_refused += 1;
                                    break;
                                }
                                Ok(Reply::Err(_)) => {
                                    errs += 1;
                                    break;
                                }
                                Err(_) => {
                                    // torn reply or dropped connection:
                                    // detected; reconnect and move on
                                    torn += 1;
                                    cl = Client::connect(addr)?;
                                    break;
                                }
                            }
                        }
                    }
                    Ok((ok, errs, busy_refused, torn, lats))
                })
            })
            .collect();
        let (mut ok, mut errs, mut busy_refused, mut torn_conns) = (0u64, 0u64, 0u64, 0u64);
        let mut lats = Vec::new();
        for h in handles {
            let (o, e, b, t, l) = h.join().unwrap()?;
            ok += o;
            errs += e;
            busy_refused += b;
            torn_conns += t;
            lats.extend(l);
        }
        println!(
            "  under faults: {ok} ok, {errs} err, {busy_refused} busy-refused, \
             {torn_conns} torn conns | injected: {} panics, {} slow chunks, \
             {} grow-fails, {} torn replies",
            plan.panics_injected.load(Ordering::Relaxed),
            plan.slows_injected.load(Ordering::Relaxed),
            plan.grow_fails_injected.load(Ordering::Relaxed),
            plan.torn_injected.load(Ordering::Relaxed),
        );

        // phase 3: blackout — every chunk panics until a shard trips
        // quarantine (deterministic: consecutive batch failures cannot
        // miss), then faults stop and recovery is timed
        fault::install(FaultSpec {
            seed: spec.seed,
            panic_per_mille: 1000,
            ..FaultSpec::default()
        });
        let t_black = Instant::now();
        let mut probe_seed = 1_000_000u64;
        while dm.quarantines.load(Ordering::Relaxed) == 0 {
            anyhow::ensure!(
                t_black.elapsed() < Duration::from_secs(30),
                "blackout never tripped a quarantine"
            );
            probe_seed += 1;
            if cl.request(None, probe_seed).is_err() {
                cl = Client::connect(addr)?;
            }
        }
        let blackout_ms = t_black.elapsed().as_millis() as u64;
        fault::clear();
        let t_clear = Instant::now();
        while !dm.health.iter().all(|h| h.state() == HealthState::Healthy) {
            anyhow::ensure!(
                t_clear.elapsed() < Duration::from_secs(10),
                "quarantined shard was never readmitted"
            );
            thread::sleep(Duration::from_millis(2));
        }
        let recovery_ms = t_clear.elapsed().as_millis() as u64;
        // the rebuilt shards must actually serve again
        for s in 0..4u64 {
            let reply = cl.request(None, 2_000_000 + s)?;
            anyhow::ensure!(
                matches!(reply, Reply::Ok { .. }),
                "post-recovery probe said {reply:?}"
            );
        }
        lats.sort_unstable();
        let p99_us = if lats.is_empty() {
            0
        } else {
            lats[(lats.len() * 99 / 100).min(lats.len() - 1)]
        };
        Ok(ChaosOutcome {
            answered: ok + errs + busy_refused + torn_conns,
            ok,
            errs,
            busy_refused,
            torn_conns,
            p99_us,
            blackout_ms,
            recovery_ms,
        })
    });
    srv.serve_while(Duration::from_secs(600), || driver.is_finished())?;
    let r = driver.join().unwrap()?;
    let elapsed = t_all.elapsed();
    // a completing shutdown IS the zero-wedged-threads check: it joins
    // every engine shard and every connection thread
    srv.shutdown();

    let quarantines = metrics.quarantines.load(Ordering::Relaxed);
    let recoveries = metrics.recoveries.load(Ordering::Relaxed);
    let panics_caught = metrics.panics_caught.load(Ordering::Relaxed);
    anyhow::ensure!(
        r.answered == total as u64,
        "every request must be answered: {} of {total}",
        r.answered
    );
    anyhow::ensure!(r.ok > 0, "chaos run completed zero successful requests");
    anyhow::ensure!(quarantines >= 1, "blackout must quarantine at least one shard");
    anyhow::ensure!(
        recoveries == quarantines,
        "every quarantine must recover: {recoveries} recoveries vs {quarantines}"
    );
    anyhow::ensure!(
        metrics.health.iter().all(|h| h.state() == HealthState::Healthy),
        "every shard must end healthy"
    );
    println!(
        "  containment: {panics_caught} panics caught | {quarantines} quarantine(s), \
         {recoveries} recovered | blackout->quarantine {} ms, clear->healthy {} ms | \
         p99 under faults {} us",
        r.blackout_ms, r.recovery_ms, r.p99_us
    );

    let mut log = BenchLog::new();
    let m = Measurement { median: elapsed, min: elapsed, max: elapsed, runs: 1 };
    log.report("chaos answered", m, r.answered, "req");
    log.report("chaos ok", m, r.ok, "req");
    log.report("chaos err replies", m, r.errs, "req");
    log.report("chaos busy refusals", m, r.busy_refused, "req");
    log.report("chaos torn connections", m, r.torn_conns, "req");
    log.report("chaos p99 under faults", m, r.p99_us, "us");
    log.report("chaos panics caught", m, panics_caught, "panic");
    log.report("chaos quarantines", m, quarantines, "quarantine");
    log.report("chaos recoveries", m, recoveries, "recovery");
    log.report("chaos blackout-to-quarantine", m, r.blackout_ms, "ms");
    log.report("chaos clear-to-healthy", m, r.recovery_ms, "ms");
    log.write_json(&out)?;
    println!("wrote {out}");
    Ok(())
}

/// Dump a model's compiled step-plan table (same rows the server's
/// `EXPLAIN <model>` verb answers): per step the kernel, shapes, the
/// cost-guided split, chunk partition size, work estimate, and the
/// predicted hardware-vs-software utilization pair.
fn cmd_explain(args: &[String]) -> Result<()> {
    // positional MODEL may appear before or after flags (`explain vgg16`
    // or `explain --threads 8 vgg16`); every explain flag takes a value,
    // so skip flag/value pairs rather than only probing args[0]
    let positional = || {
        let mut i = 0;
        while i < args.len() {
            if args[i].starts_with("--") {
                i += 2;
            } else {
                return Some(args[i].clone());
            }
        }
        None
    };
    install_cost_table(args)?;
    let model = opt(args, "--model")
        .or_else(positional)
        .unwrap_or_else(|| "tinycnn".into());
    let threads =
        resolve_threads(opt(args, "--threads").and_then(|v| v.parse().ok()).unwrap_or(0));
    let net = workload::by_name(&model)
        .with_context(|| format!("unknown network `{model}`"))?;
    let prog = cached_program(&net).map_err(anyhow::Error::msg)?;
    let plan = prog.plans_for(threads, true, false);
    println!("PLAN {} steps={} threads={threads}", net.name, prog.steps.len());
    for row in explain_rows(&net, &prog, &plan) {
        println!("{row}");
    }
    println!("END");
    let rows = plan.parallel_steps();
    println!(
        "{} of {} steps row-parallel at {threads} lanes; serial steps ride the \
         batch axis (lockstep) when batched",
        rows,
        prog.steps.len()
    );
    Ok(())
}

/// Micro-benchmark the conv hot-path kernels on *this* machine and write
/// the measured per-MAC constants to a JSON cost table
/// (`schema: neuromax-calibrate/v1`) that `serve`/`explain --cost-table`
/// install over the built-in [`SwCost`] defaults — so GEMM-vs-row
/// routing tracks the hardware actually serving, not the machine the
/// defaults were tuned on.
///
/// Sweeps three 3×3-s1 shapes spanning the planner's routing range, and
/// times the row kernels, the GEMM micro-kernel of every resolved arch
/// table (detected + forced-scalar), and the im2col packer alone. Every
/// kernel is asserted bit-exact against `Engine::conv2d` before it is
/// timed.
///
/// [`SwCost`]: neuromax::dataflow::SwCost
fn cmd_calibrate(args: &[String]) -> Result<()> {
    use neuromax::dataflow::engine::encode_cols;
    use neuromax::dataflow::{
        cpu_summary, kernel_table, pack_cols, plan_gemm_tile_with, plan_rows, plan_rows_gemm,
        scalar_table, Engine, FusedWeights, SwCost,
    };
    use neuromax::tensor::{Tensor3, Tensor4};
    use neuromax::util::bench::{blackbox, time};

    let out = opt(args, "--out").unwrap_or_else(|| "BENCH_calibrate.json".into());
    let runs: usize = opt(args, "--runs").and_then(|v| v.parse().ok()).unwrap_or(5).max(1);

    // serial engine + serial plans: the constants model per-lane cost —
    // the planner multiplies out the parallelism itself
    let eng = Engine::with_threads(1);
    let cost = SwCost::pooled();
    let detected = kernel_table();
    println!("calibrate: cpu [{}], {runs} runs/shape", cpu_summary());

    // tables to sweep: the portable scalar table always, plus the
    // detected arch table when it resolved to something wider
    let mut tables = vec![scalar_table()];
    if detected.arch != "scalar" {
        tables.push(detected);
    }

    let shapes = [(56usize, 56usize, 32usize, 16usize), (28, 28, 64, 64), (9, 9, 128, 128)];
    let (mut row_ns, mut row_macs) = (0.0f64, 0u64);
    let mut gemm_ns: Vec<(String, f64, u64)> =
        tables.iter().map(|t| (t.arch.to_string(), 0.0, 0u64)).collect();
    let (mut pack_ns, mut pack_bytes) = (0.0f64, 0u64);
    let mut detail: Vec<(String, String, f64)> = Vec::new();

    for (h, w, c, k) in shapes {
        let mut rng = SplitMix64::new(23);
        let mut a = Tensor3::new(h, w, c);
        for v in a.data.iter_mut() {
            *v = rng.range_i32(-12, 8);
        }
        let mut wc = Tensor4::new(k, 3, 3, c);
        let mut ws = Tensor4::new(k, 3, 3, c);
        for v in wc.data.iter_mut() {
            *v = rng.range_i32(-12, 8);
        }
        for v in ws.data.iter_mut() {
            *v = rng.sign();
        }
        let fw = FusedWeights::fuse(&wc, &ws);
        let (ho, wo) = (h - 2, w - 2); // 3x3 s1
        let kdim = fw.kdim();
        let macs = (ho * wo * 9 * c * k) as u64;
        let shape = format!("{h}x{w}x{c}x{k}");
        let mut cols = Vec::new();
        encode_cols(&a.data, &mut cols);
        let want = eng.conv2d(&a, &fw, 1).data;

        // row kernels
        let rplan = plan_rows(ho, macs, 1, &cost);
        let mut rout = vec![0i32; ho * wo * k];
        eng.conv2d_cols_plan(&cols, h, w, &fw, 1, &mut rout, &rplan, false, None);
        assert_eq!(rout, want, "row path must be bit-exact before timing ({shape})");
        let m = time(runs, || {
            eng.conv2d_cols_plan(&cols, h, w, &fw, 1, &mut rout, &rplan, false, None);
            blackbox(&rout);
        });
        row_ns += m.median.as_nanos() as f64;
        row_macs += macs;
        detail.push((shape.clone(), "rows".into(), m.median.as_nanos() as f64 / macs as f64));

        // each arch table's planned GEMM tile over the same plan chunks
        let gplan = plan_rows_gemm(ho, macs, wo, kdim, 1, &cost, false);
        for (ti, table) in tables.iter().enumerate() {
            let tile = plan_gemm_tile_with(table, &gplan.chunks, ho, wo, kdim);
            let mut scratch = vec![0u8; tile.scratch_len];
            let mut gout = vec![0i32; ho * wo * k];
            eng.conv2d_gemm_plan(
                &cols, h, w, &fw, 1, &mut gout, &gplan, &tile, false, None, &mut scratch,
            );
            assert_eq!(
                gout, want,
                "GEMM {} kernel must be bit-exact before timing ({shape})",
                table.arch
            );
            let m = time(runs, || {
                eng.conv2d_gemm_plan(
                    &cols, h, w, &fw, 1, &mut gout, &gplan, &tile, false, None, &mut scratch,
                );
                blackbox(&gout);
            });
            gemm_ns[ti].1 += m.median.as_nanos() as f64;
            gemm_ns[ti].2 += macs;
            detail.push((
                shape.clone(),
                format!("gemm {}x{} {}", tile.mr, tile.nr, table.arch),
                m.median.as_nanos() as f64 / macs as f64,
            ));
        }

        // im2col packing alone — the up-front price the GEMM path pays
        let mr = plan_gemm_tile_with(scalar_table(), &gplan.chunks, ho, wo, kdim).mr;
        let npix = ho * wo;
        let mut dst = vec![0u8; npix.div_ceil(mr) * mr * kdim];
        let m = time(runs, || {
            pack_cols(&cols, w, c, 3, 3, 1, wo, 0, npix, mr, &mut dst);
            blackbox(&dst);
        });
        pack_ns += m.median.as_nanos() as f64;
        pack_bytes += (npix * kdim) as u64;
    }

    let ns_per_mac = row_ns / row_macs.max(1) as f64;
    let gemm_pack_ns = pack_ns / pack_bytes.max(1) as f64;
    let per_arch: Vec<(String, f64)> = gemm_ns
        .iter()
        .map(|(arch, ns, macs)| (arch.clone(), ns / (*macs).max(1) as f64))
        .collect();
    // absent arches write 0.0 — CostOverride::from_json treats
    // non-positive values as "not calibrated" and keeps the default
    let arch_val =
        |name: &str| per_arch.iter().find(|(a, _)| a == name).map(|&(_, v)| v).unwrap_or(0.0);

    println!("\n  {:<24} {:>12}", "kernel", "ns/MAC");
    println!("  {:<24} {ns_per_mac:>12.4}", "rows (serial)");
    for (arch, v) in &per_arch {
        println!("  {:<24} {v:>12.4}", format!("gemm {arch}"));
    }
    println!("  {:<24} {gemm_pack_ns:>12.4}  (ns/byte)", "im2col pack");
    for (shape, kernel, v) in &detail {
        println!("    {shape:<18} {kernel:<22} {v:.4} ns/MAC");
    }

    // flat calibrated keys first: CostOverride::from_json takes the
    // first occurrence of each key, so the detail rows (which reuse
    // "ns_per_mac") must come after them
    let mut json = String::from("{\n  \"schema\": \"neuromax-calibrate/v1\",\n");
    json.push_str(&format!("  \"cpu\": \"{}\",\n  \"runs\": {runs},\n", cpu_summary()));
    json.push_str(&format!("  \"ns_per_mac\": {ns_per_mac:.4},\n"));
    json.push_str(&format!("  \"ns_per_mac_gemm_scalar\": {:.4},\n", arch_val("scalar")));
    json.push_str(&format!("  \"ns_per_mac_gemm_avx2\": {:.4},\n", arch_val("avx2")));
    json.push_str(&format!("  \"ns_per_mac_gemm_neon\": {:.4},\n", arch_val("neon")));
    json.push_str(&format!("  \"gemm_pack_ns\": {gemm_pack_ns:.4},\n"));
    json.push_str("  \"detail\": [");
    for (i, (shape, kernel, v)) in detail.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str(&format!(
            "\n    {{\"shape\": \"{shape}\", \"kernel\": \"{kernel}\", \"ns_per_mac\": {v:.4}}}"
        ));
    }
    json.push_str("\n  ]\n}\n");
    std::fs::write(&out, &json).with_context(|| format!("writing {out}"))?;
    println!("\nwrote {out} (load with `neuromax serve|explain --cost-table {out}`)");
    Ok(())
}

fn cmd_sweep(_args: &[String]) -> Result<()> {
    println!("design-space sweep: grid geometry vs VGG16 throughput/area\n");
    let mut rows = vec![vec![
        "matrices".into(), "rows".into(), "threads".into(), "lanes".into(),
        "VGG GOPS".into(), "LUTs".into(), "GOPS/kLUT".into(),
    ]];
    for matrices in [2usize, 4, 6, 8] {
        for threads in [1usize, 2, 3, 4] {
            let g = GridConfig { matrices, rows: 6, cols: 3, threads, clock_mhz: 200.0 };
            let rep = simulate_network(
                &g,
                &neuromax::models::vgg16::vgg16(),
                ScheduleOptions::default(),
            );
            let res = neuromax::cost::resources::table1(&g);
            let gops = g.peak_gops_paper() * rep.avg_util;
            rows.push(vec![
                matrices.to_string(),
                "6".into(),
                threads.to_string(),
                g.lanes().to_string(),
                table::f(gops, 1),
                table::f(res.luts, 0),
                table::f(gops / (res.luts / 1000.0), 2),
            ]);
        }
    }
    println!("{}", table::render(&rows));
    println!("(the paper's 6-matrix / 3-thread point maximizes GOPS per kLUT)");
    Ok(())
}
