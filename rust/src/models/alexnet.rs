//! AlexNet [1] conv workload — the paper's §5 DDR-traffic motivating
//! example ("a neural net like AlexNet, with 724M MACs, will need ≈3000M
//! DDR memory accesses").

use super::layer::{LayerDesc, Network};

/// AlexNet conv stack (227×227 input, original single-tower sizes).
pub fn alexnet() -> Network {
    alexnet_scaled("AlexNet", 227, &[96, 256, 384, 384, 256])
}

/// Scaled-down AlexNet shape profile (same 5-conv/2-pool topology) for
/// fast end-to-end execution tests.
pub fn alexnet_test() -> Network {
    alexnet_scaled("AlexNet-test", 51, &[12, 32, 48, 48, 32])
}

/// AlexNet topology generator: 11×11 s4 stem, two pooled 5×5/3×3 stages,
/// then three 3×3 convs; dims chain-propagated from `hw0`.
fn alexnet_scaled(name: &str, hw0: usize, c: &[usize; 5]) -> Network {
    let h1 = (hw0 - 11) / 4 + 1;
    let p1 = (h1 - 3) / 2 + 1;
    let p2 = (p1 - 3) / 2 + 1;
    let l = vec![
        LayerDesc::conv("CONV1", 11, 4, 0, hw0, hw0, 3, c[0]),
        LayerDesc::pool("POOL1", 3, 2, h1, h1, c[0]),
        LayerDesc::conv("CONV2", 5, 1, 2, p1, p1, c[0], c[1]),
        LayerDesc::pool("POOL2", 3, 2, p1, p1, c[1]),
        LayerDesc::conv("CONV3", 3, 1, 1, p2, p2, c[1], c[2]),
        LayerDesc::conv("CONV4", 3, 1, 1, p2, p2, c[2], c[3]),
        LayerDesc::conv("CONV5", 3, 1, 1, p2, p2, c[3], c[4]),
    ];
    Network { name: name.into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_near_the_literature_value() {
        // paper §5 quotes 724M MACs (grouped two-tower conv + fc). The
        // ungrouped single-tower conv stack modelled here is ≈ 1.08 GMAC
        // (the familiar 666M figure halves conv2/4/5 via grouping).
        let m = alexnet().total_macs() as f64 / 1e6;
        assert!((1000.0..1150.0).contains(&m), "got {m} MMAC");
    }

    #[test]
    fn pool_dims() {
        let net = alexnet();
        net.validate_chaining().unwrap_or_else(|e| panic!("{e}"));
    }

    #[test]
    fn test_profile_chains_and_shrinks() {
        let small = alexnet_test();
        small.validate_chaining().unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(small.layers.len(), alexnet().layers.len());
        assert!(small.total_macs() < alexnet().total_macs() / 500);
    }
}
