//! AlexNet [1] conv workload — the paper's §5 DDR-traffic motivating
//! example ("a neural net like AlexNet, with 724M MACs, will need ≈3000M
//! DDR memory accesses").

use super::layer::{LayerDesc, Network};

/// AlexNet conv stack (227×227 input, original single-tower sizes).
pub fn alexnet() -> Network {
    let l = vec![
        LayerDesc::conv("CONV1", 11, 4, 0, 227, 227, 3, 96),
        LayerDesc::pool("POOL1", 3, 2, 55, 55, 96),
        LayerDesc::conv("CONV2", 5, 1, 2, 27, 27, 96, 256),
        LayerDesc::pool("POOL2", 3, 2, 27, 27, 256),
        LayerDesc::conv("CONV3", 3, 1, 1, 13, 13, 256, 384),
        LayerDesc::conv("CONV4", 3, 1, 1, 13, 13, 384, 384),
        LayerDesc::conv("CONV5", 3, 1, 1, 13, 13, 384, 256),
    ];
    Network { name: "AlexNet".into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macs_near_the_literature_value() {
        // paper §5 quotes 724M MACs (grouped two-tower conv + fc). The
        // ungrouped single-tower conv stack modelled here is ≈ 1.08 GMAC
        // (the familiar 666M figure halves conv2/4/5 via grouping).
        let m = alexnet().total_macs() as f64 / 1e6;
        assert!((1000.0..1150.0).contains(&m), "got {m} MMAC");
    }

    #[test]
    fn pool_dims() {
        let net = alexnet();
        net.validate_chaining().unwrap_or_else(|e| panic!("{e}"));
    }
}
