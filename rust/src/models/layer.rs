//! CNN layer descriptors: the workload language shared by the scheduler,
//! the cycle simulator, the baselines and the benchmark harness.

/// Layer operation type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Standard convolution `kh × kw`.
    Conv { kh: usize, kw: usize, stride: usize, pad: usize },
    /// Depthwise convolution `k × k` (cout == cin).
    Depthwise { k: usize, stride: usize, pad: usize },
    /// Pointwise (1×1) convolution.
    Pointwise { stride: usize },
    /// Pooling (max or average).
    Pool { k: usize, stride: usize, max: bool },
    /// Fully connected (flattened input).
    Fc,
}

/// One layer of a CNN workload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerDesc {
    pub name: String,
    pub op: Op,
    pub hin: usize,
    pub win: usize,
    pub cin: usize,
    pub cout: usize,
}

impl LayerDesc {
    pub fn conv(
        name: &str, k: usize, stride: usize, pad: usize,
        hin: usize, win: usize, cin: usize, cout: usize,
    ) -> Self {
        LayerDesc {
            name: name.into(),
            op: Op::Conv { kh: k, kw: k, stride, pad },
            hin, win, cin, cout,
        }
    }

    pub fn depthwise(name: &str, stride: usize, hin: usize, win: usize, c: usize) -> Self {
        LayerDesc {
            name: name.into(),
            op: Op::Depthwise { k: 3, stride, pad: 1 },
            hin, win, cin: c, cout: c,
        }
    }

    pub fn pointwise(name: &str, hin: usize, win: usize, cin: usize, cout: usize) -> Self {
        LayerDesc { name: name.into(), op: Op::Pointwise { stride: 1 }, hin, win, cin, cout }
    }

    pub fn pool(name: &str, k: usize, stride: usize, hin: usize, win: usize, c: usize) -> Self {
        LayerDesc {
            name: name.into(),
            op: Op::Pool { k, stride, max: true },
            hin, win, cin: c, cout: c,
        }
    }

    pub fn avgpool(name: &str, k: usize, stride: usize, hin: usize, win: usize, c: usize) -> Self {
        LayerDesc {
            name: name.into(),
            op: Op::Pool { k, stride, max: false },
            hin, win, cin: c, cout: c,
        }
    }

    pub fn fc(name: &str, cin: usize, cout: usize) -> Self {
        LayerDesc { name: name.into(), op: Op::Fc, hin: 1, win: 1, cin, cout }
    }

    /// Padded input dims.
    pub fn padded(&self) -> (usize, usize) {
        let p = match self.op {
            Op::Conv { pad, .. } => pad,
            Op::Depthwise { pad, .. } => pad,
            _ => 0,
        };
        (self.hin + 2 * p, self.win + 2 * p)
    }

    /// Kernel size (kh, kw) and stride.
    pub fn kernel(&self) -> (usize, usize, usize) {
        match self.op {
            Op::Conv { kh, kw, stride, .. } => (kh, kw, stride),
            Op::Depthwise { k, stride, .. } => (k, k, stride),
            Op::Pointwise { stride } => (1, 1, stride),
            Op::Pool { k, stride, .. } => (k, k, stride),
            Op::Fc => (1, 1, 1),
        }
    }

    /// Output spatial dims (valid conv over the padded input).
    pub fn out_dims(&self) -> (usize, usize) {
        let (hp, wp) = self.padded();
        let (kh, kw, s) = self.kernel();
        assert!(hp >= kh && wp >= kw, "layer {} too small", self.name);
        ((hp - kh) / s + 1, (wp - kw) / s + 1)
    }

    /// Multiply-accumulate count (pools count 0).
    pub fn macs(&self) -> u64 {
        let (ho, wo) = self.out_dims();
        let (kh, kw, _) = self.kernel();
        match self.op {
            Op::Conv { .. } => (ho * wo * kh * kw * self.cin * self.cout) as u64,
            Op::Depthwise { .. } => (ho * wo * kh * kw * self.cin) as u64,
            Op::Pointwise { .. } => (ho * wo * self.cin * self.cout) as u64,
            Op::Pool { .. } => 0,
            Op::Fc => (self.cin * self.cout) as u64,
        }
    }

    /// Weight parameter count.
    pub fn params(&self) -> u64 {
        let (kh, kw, _) = self.kernel();
        match self.op {
            Op::Conv { .. } => (kh * kw * self.cin * self.cout) as u64,
            Op::Depthwise { .. } => (kh * kw * self.cin) as u64,
            Op::Pointwise { .. } => (self.cin * self.cout) as u64,
            Op::Pool { .. } => 0,
            Op::Fc => (self.cin * self.cout) as u64,
        }
    }

    /// Is this a compute (MAC) layer the accelerator runs on the PE grid?
    pub fn is_compute(&self) -> bool {
        !matches!(self.op, Op::Pool { .. })
    }
}

/// A full network workload.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub layers: Vec<LayerDesc>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    pub fn compute_layers(&self) -> impl Iterator<Item = &LayerDesc> {
        self.layers.iter().filter(|l| l.is_compute())
    }

    /// Check layer shapes chain correctly (cout/out dims feed the next
    /// layer) — a structural sanity test for the model zoo.
    pub fn validate_chaining(&self) -> Result<(), String> {
        for w in self.layers.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            if let Op::Fc = b.op {
                let (ho, wo) = a.out_dims();
                if ho * wo * a.cout != b.cin {
                    return Err(format!(
                        "{} -> {}: flatten {}x{}x{} != {}",
                        a.name, b.name, ho, wo, a.cout, b.cin
                    ));
                }
                continue;
            }
            let (ho, wo) = a.out_dims();
            if (ho, wo) != (b.hin, b.win) || a.cout != b.cin {
                return Err(format!(
                    "{} -> {}: out {}x{}x{} != in {}x{}x{}",
                    a.name, b.name, ho, wo, a.cout, b.hin, b.win, b.cin
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shapes_and_macs() {
        // VGG16 conv1_2: 224×224×64 ⊛ 3×3×64×64, pad 1
        let l = LayerDesc::conv("conv1_2", 3, 1, 1, 224, 224, 64, 64);
        assert_eq!(l.out_dims(), (224, 224));
        assert_eq!(l.macs(), 224 * 224 * 9 * 64 * 64);
        assert_eq!(l.params(), 9 * 64 * 64);
    }

    #[test]
    fn stride2_out_dims() {
        let l = LayerDesc::conv("s2", 3, 2, 1, 224, 224, 3, 32);
        assert_eq!(l.out_dims(), (112, 112));
    }

    #[test]
    fn depthwise_macs_scale_with_c_not_c_squared() {
        let l = LayerDesc::depthwise("dw", 1, 56, 56, 128);
        assert_eq!(l.macs(), 56 * 56 * 9 * 128);
    }

    #[test]
    fn pool_has_no_macs() {
        let l = LayerDesc::pool("p", 2, 2, 112, 112, 64);
        assert_eq!(l.macs(), 0);
        assert_eq!(l.out_dims(), (56, 56));
        assert!(!l.is_compute());
    }

    #[test]
    fn chaining_catches_mismatches() {
        let good = Network {
            name: "ok".into(),
            layers: vec![
                LayerDesc::conv("a", 3, 1, 1, 8, 8, 3, 16),
                LayerDesc::conv("b", 3, 1, 1, 8, 8, 16, 32),
            ],
        };
        assert!(good.validate_chaining().is_ok());
        let bad = Network {
            name: "bad".into(),
            layers: vec![
                LayerDesc::conv("a", 3, 1, 1, 8, 8, 3, 16),
                LayerDesc::conv("b", 3, 1, 1, 8, 8, 99, 32),
            ],
        };
        assert!(bad.validate_chaining().is_err());
    }
}
