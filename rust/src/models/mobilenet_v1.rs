//! MobileNet v1 [4] workload (224×224×3, depthwise-separable stack).

use super::layer::{LayerDesc, Network};

/// (stride of dw, width multiple of pw cout) per separable pair.
const PAIRS: [(usize, usize); 13] = [
    (1, 2), (2, 4), (1, 4), (2, 8), (1, 8), (2, 16),
    (1, 16), (1, 16), (1, 16), (1, 16), (1, 16),
    (2, 32), (1, 32),
];

/// Standard MobileNet v1 body: first conv s2, then 13 dw/pw pairs.
pub fn mobilenet_v1() -> Network {
    mobilenet_scaled("MobileNetV1", 224, 32)
}

/// Scaled-down MobileNet v1 shape profile (same 27-layer topology) for
/// fast end-to-end execution tests.
pub fn mobilenet_v1_test() -> Network {
    mobilenet_scaled("MobileNetV1-test", 32, 4)
}

/// MobileNet topology generator: stem conv s2 to `c0` channels, then the
/// 13 separable pairs with couts `c0 × PAIRS[i].1`; dims chain-propagated.
fn mobilenet_scaled(name: &str, hw0: usize, c0: usize) -> Network {
    let mut l = Vec::new();
    l.push(LayerDesc::conv("CONV1", 3, 2, 1, hw0, hw0, 3, c0));
    let mut hw = hw0 / 2;
    let mut cin = c0;
    for (i, &(s, wm)) in PAIRS.iter().enumerate() {
        let cout = c0 * wm;
        l.push(LayerDesc::depthwise(&format!("DW{}", i + 1), s, hw, hw, cin));
        let hw_out = if s == 2 { hw / 2 } else { hw };
        l.push(LayerDesc::pointwise(&format!("PW{}", i + 1), hw_out, hw_out, cin, cout));
        hw = hw_out;
        cin = cout;
    }
    Network { name: name.into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains() {
        mobilenet_v1().validate_chaining().unwrap();
        mobilenet_v1_test().validate_chaining().unwrap();
    }

    #[test]
    fn ends_at_7x7x1024() {
        let net = mobilenet_v1();
        let last = net.layers.last().unwrap();
        assert_eq!(last.out_dims(), (7, 7));
        assert_eq!(last.cout, 1024);
    }

    #[test]
    fn total_macs_about_0_57_gmac() {
        let g = mobilenet_v1().total_macs() as f64 / 1e9;
        assert!((0.52..0.62).contains(&g), "got {g} GMAC");
    }

    #[test]
    fn pointwise_dominates_macs() {
        let net = mobilenet_v1();
        let pw: u64 = net.layers.iter()
            .filter(|l| matches!(l.op, super::super::layer::Op::Pointwise { .. }))
            .map(|l| l.macs()).sum();
        assert!(pw as f64 / net.total_macs() as f64 > 0.7);
    }

    #[test]
    fn test_profile_ends_at_1x1x128() {
        let net = mobilenet_v1_test();
        let last = net.layers.last().unwrap();
        assert_eq!(last.out_dims(), (1, 1));
        assert_eq!(last.cout, 128);
    }
}
