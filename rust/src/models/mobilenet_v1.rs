//! MobileNet v1 [4] workload (224×224×3, depthwise-separable stack).

use super::layer::{LayerDesc, Network};

/// Standard MobileNet v1 body: first conv s2, then 13 dw/pw pairs.
pub fn mobilenet_v1() -> Network {
    let mut l = Vec::new();
    l.push(LayerDesc::conv("CONV1", 3, 2, 1, 224, 224, 3, 32));
    // (stride of dw, cout of pw) per pair, input dims tracked manually
    let spec: &[(usize, usize)] = &[
        (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
        (1, 512), (1, 512), (1, 512), (1, 512), (1, 512),
        (2, 1024), (1, 1024),
    ];
    let mut hw = 112;
    let mut cin = 32;
    for (i, &(s, cout)) in spec.iter().enumerate() {
        l.push(LayerDesc::depthwise(&format!("DW{}", i + 1), s, hw, hw, cin));
        let hw_out = if s == 2 { hw / 2 } else { hw };
        l.push(LayerDesc::pointwise(&format!("PW{}", i + 1), hw_out, hw_out, cin, cout));
        hw = hw_out;
        cin = cout;
    }
    Network { name: "MobileNetV1".into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains() {
        mobilenet_v1().validate_chaining().unwrap();
    }

    #[test]
    fn ends_at_7x7x1024() {
        let net = mobilenet_v1();
        let last = net.layers.last().unwrap();
        assert_eq!(last.out_dims(), (7, 7));
        assert_eq!(last.cout, 1024);
    }

    #[test]
    fn total_macs_about_0_57_gmac() {
        let g = mobilenet_v1().total_macs() as f64 / 1e9;
        assert!((0.52..0.62).contains(&g), "got {g} GMAC");
    }

    #[test]
    fn pointwise_dominates_macs() {
        let net = mobilenet_v1();
        let pw: u64 = net.layers.iter()
            .filter(|l| matches!(l.op, super::super::layer::Op::Pointwise { .. }))
            .map(|l| l.macs()).sum();
        assert!(pw as f64 / net.total_macs() as f64 > 0.7);
    }
}
