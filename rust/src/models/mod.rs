//! CNN workload zoo: layer descriptors and the networks the paper
//! evaluates (VGG-16, MobileNet v1, ResNet-34 for Fig. 19/20; SqueezeNet
//! for Fig. 1; AlexNet for the §5 DDR motivation; TinyCNN end-to-end).

pub mod alexnet;
pub mod layer;
pub mod mobilenet_v1;
pub mod resnet34;
pub mod runner;
pub mod squeezenet;
pub mod tinycnn;
pub mod vgg16;
pub mod workload;

pub use layer::{LayerDesc, Network, Op};
pub use runner::{FusedNet, NetWeights};
