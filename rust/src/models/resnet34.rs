//! ResNet-34 workload (224×224×3; basic blocks, conv layers only — the
//! identity residual adds run on the post-processing path, not the PE
//! grid; projection shortcuts are explicit 1×1 s2 layers and merge with
//! the block output in the generic forward's residual routing).

use super::layer::{LayerDesc, Network};

/// Basic blocks per stage.
const BLOCKS: [usize; 4] = [3, 4, 6, 3];

/// ResNet-34: 7×7 s2 stem, maxpool, then stages of basic blocks
/// (3, 4, 6, 3) with channel doubling and stride-2 entry convs.
pub fn resnet34() -> Network {
    resnet34_scaled("ResNet34", 224, 64)
}

/// Scaled-down ResNet-34 shape profile (same 36-compute-layer topology)
/// for fast end-to-end execution tests.
pub fn resnet34_test() -> Network {
    resnet34_scaled("ResNet34-test", 32, 8)
}

/// ResNet-34 topology generator: stem to `c0` channels, stages at
/// `c0 × {1,2,4,8}`; dims chain-propagated from `hw0`.
fn resnet34_scaled(name: &str, hw0: usize, c0: usize) -> Network {
    let mut l = Vec::new();
    l.push(LayerDesc::conv("CONV1", 7, 2, 3, hw0, hw0, 3, c0));
    let mut hw = (hw0 + 2 * 3 - 7) / 2 + 1;
    l.push(LayerDesc::pool("POOL1", 2, 2, hw, hw, c0));
    hw /= 2;

    let mut cin = c0;
    for (si, &blocks) in BLOCKS.iter().enumerate() {
        let ch = c0 << si;
        for b in 0..blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            let name_a = format!("S{}B{}_A", si + 1, b + 1);
            let name_b = format!("S{}B{}_B", si + 1, b + 1);
            l.push(LayerDesc::conv(&name_a, 3, stride, 1, hw, hw, cin, ch));
            let hw_out = if stride == 2 { hw / 2 } else { hw };
            l.push(LayerDesc::conv(&name_b, 3, 1, 1, hw_out, hw_out, ch, ch));
            if stride == 2 {
                // projection shortcut (1×1 s2) — extra compute layer
                l.push(LayerDesc {
                    name: format!("S{}B{}_DS", si + 1, b + 1),
                    op: super::layer::Op::Pointwise { stride: 2 },
                    hin: hw,
                    win: hw,
                    cin,
                    cout: ch,
                });
            }
            hw = hw_out;
            cin = ch;
        }
    }
    Network { name: name.into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count_and_macs() {
        let net = resnet34();
        // 1 stem + 16 blocks × 2 + 3 downsample 1×1 = 36 compute layers
        assert_eq!(net.compute_layers().count(), 36);
        let g = net.total_macs() as f64 / 1e9;
        assert!((3.4..3.9).contains(&g), "got {g} GMAC");
    }

    #[test]
    fn stage_dims_halve() {
        let net = resnet34();
        let s4 = net.layers.iter().find(|l| l.name == "S4B1_A").unwrap();
        assert_eq!((s4.hin, s4.win, s4.cin, s4.cout), (14, 14, 256, 512));
        assert_eq!(s4.out_dims(), (7, 7));
    }

    #[test]
    fn test_profile_same_topology() {
        let small = resnet34_test();
        assert_eq!(small.compute_layers().count(), 36);
        assert_eq!(small.layers.len(), resnet34().layers.len());
        let last = small.layers.last().unwrap();
        assert_eq!(last.out_dims(), (1, 1));
        assert_eq!(last.cout, 64);
    }
}
