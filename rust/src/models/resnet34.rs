//! ResNet-34 workload (224×224×3; basic blocks, conv layers only — the
//! residual adds run on the post-processing path, not the PE grid).

use super::layer::{LayerDesc, Network};

/// ResNet-34: 7×7 s2 stem, maxpool, then stages of basic blocks
/// (3, 4, 6, 3) with channel doubling and stride-2 entry convs.
pub fn resnet34() -> Network {
    let mut l = Vec::new();
    l.push(LayerDesc::conv("CONV1", 7, 2, 3, 224, 224, 3, 64));
    l.push(LayerDesc::pool("POOL1", 3, 2, 112, 112, 64));
    // NB: 112 pad... standard resnet pools 112->56 with pad 1; model as
    // k=2 s=2 for shape bookkeeping simplicity of the chain.
    l.pop();
    l.push(LayerDesc::pool("POOL1", 2, 2, 112, 112, 64));

    let stages: &[(usize, usize, usize)] = &[
        // (blocks, channels, input hw)
        (3, 64, 56),
        (4, 128, 56),
        (6, 256, 28),
        (3, 512, 14),
    ];
    let mut cin = 64;
    for (si, &(blocks, ch, hw_in)) in stages.iter().enumerate() {
        let mut hw = hw_in;
        for b in 0..blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            let name_a = format!("S{}B{}_A", si + 1, b + 1);
            let name_b = format!("S{}B{}_B", si + 1, b + 1);
            l.push(LayerDesc::conv(&name_a, 3, stride, 1, hw, hw, cin, ch));
            let hw_out = if stride == 2 { hw / 2 } else { hw };
            l.push(LayerDesc::conv(&name_b, 3, 1, 1, hw_out, hw_out, ch, ch));
            if stride == 2 {
                // projection shortcut (1×1 s2) — extra compute layer
                l.push(LayerDesc {
                    name: format!("S{}B{}_DS", si + 1, b + 1),
                    op: super::layer::Op::Pointwise { stride: 2 },
                    hin: hw,
                    win: hw,
                    cin,
                    cout: ch,
                });
            }
            hw = hw_out;
            cin = ch;
        }
    }
    Network { name: "ResNet34".into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count_and_macs() {
        let net = resnet34();
        // 1 stem + 16 blocks × 2 + 3 downsample 1×1 = 36 compute layers
        assert_eq!(net.compute_layers().count(), 36);
        let g = net.total_macs() as f64 / 1e9;
        assert!((3.4..3.9).contains(&g), "got {g} GMAC");
    }

    #[test]
    fn stage_dims_halve() {
        let net = resnet34();
        let s4 = net.layers.iter().find(|l| l.name == "S4B1_A").unwrap();
        assert_eq!((s4.hin, s4.win, s4.cin, s4.cout), (14, 14, 256, 512));
        assert_eq!(s4.out_dims(), (7, 7));
    }
}
