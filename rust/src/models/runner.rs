//! Generic per-network weight container: seed-deterministic (codes,
//! signs) tensors for every compute layer of any zoo [`Network`], plus
//! the engine-fused form shared across requests. This is what lets the
//! serving stack execute the whole model zoo instead of one hand-wired
//! net: `dataflow::forward` consumes these alongside a [`ForwardPlan`].
//!
//! The random distribution (≈8% exact zeros, small codes) and the single
//! PRNG stream across layers are identical to the original TinyCNN
//! generator, so `NetWeights::random(&tinycnn(), seed)` reproduces
//! `TinyCnnWeights::random(seed)` tensor-for-tensor — the AOT HLO
//! artifacts and the python test vectors keep verifying unchanged.
//!
//! [`ForwardPlan`]: crate::dataflow::forward::ForwardPlan

use super::layer::{LayerDesc, Network, Op};
use crate::dataflow::engine::FusedWeights;
use crate::lns::logquant::ZERO_CODE;
use crate::tensor::{Tensor3, Tensor4};
use crate::util::prng::SplitMix64;

/// Weight tensor shape `[K, kh, kw, C]` for a layer, or `None` for
/// weight-free layers (pools).
pub fn weight_shape(l: &LayerDesc) -> Option<(usize, usize, usize, usize)> {
    match l.op {
        Op::Conv { kh, kw, .. } => Some((l.cout, kh, kw, l.cin)),
        Op::Depthwise { k, .. } => Some((l.cin, k, k, 1)),
        Op::Pointwise { .. } => Some((l.cout, 1, 1, l.cin)),
        Op::Fc => Some((l.cout, 1, 1, l.cin)),
        Op::Pool { .. } => None,
    }
}

/// A full set of weights for one network: per-layer `(codes, signs)`
/// tensor pairs aligned with `net.layers` (pools hold `None`).
#[derive(Clone, Debug)]
pub struct NetWeights {
    pub layers: Vec<Option<(Tensor4, Tensor4)>>,
}

impl NetWeights {
    /// Random plausible weights: mostly small codes, ~8% exact zeros —
    /// the same distribution the python test-vector generator uses.
    /// One PRNG stream across all layers, in layer order.
    pub fn random(net: &Network, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let layers = net
            .layers
            .iter()
            .map(|l| {
                weight_shape(l).map(|(k, kh, kw, c)| {
                    let mut tc = Tensor4::new(k, kh, kw, c);
                    let mut ts = Tensor4::new(k, kh, kw, c);
                    for v in tc.data.iter_mut() {
                        *v = if rng.bool(0.08) { ZERO_CODE } else { rng.range_i32(-12, 5) };
                    }
                    for v in ts.data.iter_mut() {
                        *v = rng.sign();
                    }
                    (tc, ts)
                })
            })
            .collect();
        NetWeights { layers }
    }

    /// Fuse every layer's (codes, signs) pair into engine LUT-row
    /// indices — built once, shared by every request/batch element.
    pub fn fuse(&self) -> FusedNet {
        FusedNet {
            layers: self
                .layers
                .iter()
                .map(|w| w.as_ref().map(|(c, s)| FusedWeights::fuse(c, s)))
                .collect(),
        }
    }

    /// Total weight parameters held (sanity/reporting).
    pub fn total_params(&self) -> usize {
        self.layers
            .iter()
            .flatten()
            .map(|(c, _)| c.len())
            .sum()
    }
}

/// A network's weights pre-fused for `dataflow::engine`, aligned with
/// `net.layers` (pools hold `None`). One `FusedNet` per (model, seed)
/// is shared by every request and every program executor lane.
#[derive(Clone, Debug)]
pub struct FusedNet {
    pub layers: Vec<Option<FusedWeights>>,
}

impl FusedNet {
    /// Total fused-weight footprint in bytes (one `u8` per parameter —
    /// the resident working set a serving shard streams per layer).
    pub fn bytes(&self) -> usize {
        self.layers.iter().flatten().map(|f| f.bytes()).sum()
    }
}

/// Random input codes (log-quantized image) for a network's declared
/// input dims — same distribution/stream as the original TinyCNN input
/// generator.
pub fn random_input_for(net: &Network, seed: u64) -> Tensor3 {
    let l0 = &net.layers[0];
    random_input_dims(l0.hin, l0.win, l0.cin, seed)
}

/// Random input codes for explicit dims.
pub fn random_input_dims(h: usize, w: usize, c: usize, seed: u64) -> Tensor3 {
    let mut rng = SplitMix64::new(seed);
    let mut a = Tensor3::new(h, w, c);
    for v in a.data.iter_mut() {
        *v = if rng.bool(0.05) { ZERO_CODE } else { rng.range_i32(-10, 5) };
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{squeezenet::squeezenet_test, tinycnn::tinycnn, workload};

    #[test]
    fn shapes_follow_ops() {
        let l = LayerDesc::depthwise("dw", 1, 8, 8, 16);
        assert_eq!(weight_shape(&l), Some((16, 3, 3, 1)));
        let p = LayerDesc::pool("p", 2, 2, 8, 8, 16);
        assert_eq!(weight_shape(&p), None);
        let f = LayerDesc::fc("fc", 128, 10);
        assert_eq!(weight_shape(&f), Some((10, 1, 1, 128)));
    }

    #[test]
    fn deterministic_per_seed() {
        let net = squeezenet_test();
        let a = NetWeights::random(&net, 11);
        let b = NetWeights::random(&net, 11);
        let c = NetWeights::random(&net, 12);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(
                x.as_ref().map(|(t, s)| (&t.data, &s.data)),
                y.as_ref().map(|(t, s)| (&t.data, &s.data))
            );
        }
        let first = |w: &NetWeights| w.layers[0].as_ref().unwrap().0.data.clone();
        assert_ne!(first(&a), first(&c));
    }

    #[test]
    fn pools_are_weight_free_and_fused_layers_align() {
        let net = squeezenet_test();
        let w = NetWeights::random(&net, 3);
        let f = w.fuse();
        assert_eq!(w.layers.len(), net.layers.len());
        assert_eq!(f.layers.len(), net.layers.len());
        for (l, (wl, fl)) in net.layers.iter().zip(w.layers.iter().zip(&f.layers)) {
            assert_eq!(wl.is_some(), l.is_compute(), "{}", l.name);
            assert_eq!(fl.is_some(), l.is_compute(), "{}", l.name);
        }
        // one fused byte per parameter
        assert_eq!(f.bytes(), w.total_params());
    }

    #[test]
    fn reproduces_tinycnn_generator_exactly() {
        // the pre-refactor TinyCNN generator, inlined: one stream, codes
        // then signs per layer over the fixed shape list
        let shapes = [(8, 3, 3, 4), (16, 3, 3, 8), (24, 1, 1, 16), (32, 3, 3, 24), (10, 1, 1, 512)];
        let mut rng = SplitMix64::new(77);
        let mut legacy = Vec::new();
        for (k, kh, kw, c) in shapes {
            let mut tc = Tensor4::new(k, kh, kw, c);
            let mut ts = Tensor4::new(k, kh, kw, c);
            for v in tc.data.iter_mut() {
                *v = if rng.bool(0.08) { ZERO_CODE } else { rng.range_i32(-12, 5) };
            }
            for v in ts.data.iter_mut() {
                *v = rng.sign();
            }
            legacy.push((tc, ts));
        }
        let w = NetWeights::random(&tinycnn(), 77);
        assert_eq!(w.layers.len(), legacy.len());
        for (got, want) in w.layers.iter().zip(&legacy) {
            let (gc, gs) = got.as_ref().unwrap();
            assert_eq!(gc.data, want.0.data);
            assert_eq!(gs.data, want.1.data);
        }
    }

    #[test]
    fn every_zoo_model_gets_weights() {
        for name in workload::ZOO_NAMES {
            let net = workload::by_name(name).unwrap();
            let w = NetWeights::random(&net, 1);
            assert!(w.total_params() > 0, "{name}");
        }
    }
}
