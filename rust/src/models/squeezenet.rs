//! SqueezeNet v1.0 [16] workload (fire modules: squeeze 1×1 + expand
//! 1×1/3×3). Used by the Fig. 1 quantization study and as a serving
//! workload. Expand branches are two layers consuming the same squeeze
//! output; the generic forward's shape-directed routing runs them as a
//! branch + channel concat. Ends with the classifier conv and its global
//! average pool (the avg-pool kernel runs on Q19.12 magnitudes).

use super::layer::{LayerDesc, Network};

fn fire(l: &mut Vec<LayerDesc>, name: &str, hw: usize, cin: usize, s: usize, e1: usize, e3: usize) {
    l.push(LayerDesc::pointwise(&format!("{name}_SQ"), hw, hw, cin, s));
    l.push(LayerDesc::pointwise(&format!("{name}_E1"), hw, hw, s, e1));
    l.push(LayerDesc::conv(&format!("{name}_E3"), 3, 1, 1, hw, hw, s, e3));
}

/// SqueezeNet v1.0 conv stack.
pub fn squeezenet() -> Network {
    squeezenet_scaled("SqueezeNet", 224, 8)
}

/// Scaled-down SqueezeNet shape profile (same fire-module topology) for
/// fast end-to-end execution tests.
pub fn squeezenet_test() -> Network {
    squeezenet_scaled("SqueezeNet-test", 32, 1)
}

/// SqueezeNet topology generator: channel counts are `base × d` with
/// `d = 8` at full size; dims chain-propagated from `hw0`.
fn squeezenet_scaled(name: &str, hw0: usize, d: usize) -> Network {
    let mut l = Vec::new();
    l.push(LayerDesc::conv("CONV1", 7, 2, 3, hw0, hw0, 3, 12 * d));
    let mut hw = (hw0 + 2 * 3 - 7) / 2 + 1;
    l.push(LayerDesc::pool("POOL1", 2, 2, hw, hw, 12 * d));
    hw /= 2;
    fire(&mut l, "FIRE2", hw, 12 * d, 2 * d, 8 * d, 8 * d);
    fire(&mut l, "FIRE3", hw, 16 * d, 2 * d, 8 * d, 8 * d);
    fire(&mut l, "FIRE4", hw, 16 * d, 4 * d, 16 * d, 16 * d);
    l.push(LayerDesc::pool("POOL4", 2, 2, hw, hw, 32 * d));
    hw /= 2;
    fire(&mut l, "FIRE5", hw, 32 * d, 4 * d, 16 * d, 16 * d);
    fire(&mut l, "FIRE6", hw, 32 * d, 6 * d, 24 * d, 24 * d);
    fire(&mut l, "FIRE7", hw, 48 * d, 6 * d, 24 * d, 24 * d);
    fire(&mut l, "FIRE8", hw, 48 * d, 8 * d, 32 * d, 32 * d);
    l.push(LayerDesc::pool("POOL8", 2, 2, hw, hw, 64 * d));
    hw /= 2;
    fire(&mut l, "FIRE9", hw, 64 * d, 8 * d, 32 * d, 32 * d);
    l.push(LayerDesc::pointwise("CONV10", hw, hw, 64 * d, 125 * d));
    l.push(LayerDesc::avgpool("POOL10", hw, 1, hw, hw, 125 * d));
    Network { name: name.into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::layer::Op;

    #[test]
    fn structure() {
        let net = squeezenet();
        assert_eq!(net.layers.iter().filter(|l| l.name.ends_with("_SQ")).count(), 8);
        let g = net.total_macs() as f64 / 1e9;
        assert!((0.7..1.0).contains(&g), "got {g} GMAC");
    }

    #[test]
    fn full_size_matches_v1_0_channels() {
        let net = squeezenet();
        let c1 = net.layers.iter().find(|l| l.name == "CONV1").unwrap();
        assert_eq!(c1.cout, 96);
        let sq = net.layers.iter().find(|l| l.name == "FIRE9_SQ").unwrap();
        assert_eq!((sq.cin, sq.cout), (512, 64));
        let c10 = net.layers.iter().find(|l| l.name == "CONV10").unwrap();
        assert_eq!((c10.cin, c10.cout), (512, 1000));
    }

    #[test]
    fn ends_with_global_avgpool() {
        for net in [squeezenet(), squeezenet_test()] {
            let last = net.layers.last().unwrap();
            assert!(matches!(last.op, Op::Pool { max: false, .. }), "{}", net.name);
            assert_eq!(last.out_dims(), (1, 1), "{}", net.name);
        }
    }

    #[test]
    fn test_profile_same_topology() {
        let (full, small) = (squeezenet(), squeezenet_test());
        assert_eq!(full.layers.len(), small.layers.len());
        for (a, b) in full.layers.iter().zip(&small.layers) {
            assert_eq!(a.name, b.name);
        }
    }
}
