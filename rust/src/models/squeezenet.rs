//! SqueezeNet v1.0 [16] workload (fire modules: squeeze 1×1 + expand
//! 1×1/3×3). Used by the Fig. 1 quantization study and as a serving
//! workload; expand branches are modelled as two parallel layers.

use super::layer::{LayerDesc, Network};

fn fire(l: &mut Vec<LayerDesc>, name: &str, hw: usize, cin: usize, s: usize, e1: usize, e3: usize) {
    l.push(LayerDesc::pointwise(&format!("{name}_SQ"), hw, hw, cin, s));
    l.push(LayerDesc::pointwise(&format!("{name}_E1"), hw, hw, s, e1));
    l.push(LayerDesc::conv(&format!("{name}_E3"), 3, 1, 1, hw, hw, s, e3));
}

/// SqueezeNet v1.0 conv stack.
pub fn squeezenet() -> Network {
    let mut l = Vec::new();
    l.push(LayerDesc::conv("CONV1", 7, 2, 3, 224, 224, 3, 96));
    l.push(LayerDesc::pool("POOL1", 2, 2, 112, 112, 96));
    fire(&mut l, "FIRE2", 56, 96, 16, 64, 64);
    fire(&mut l, "FIRE3", 56, 128, 16, 64, 64);
    fire(&mut l, "FIRE4", 56, 128, 32, 128, 128);
    l.push(LayerDesc::pool("POOL4", 2, 2, 56, 56, 256));
    fire(&mut l, "FIRE5", 28, 256, 32, 128, 128);
    fire(&mut l, "FIRE6", 28, 256, 48, 192, 192);
    fire(&mut l, "FIRE7", 28, 384, 48, 192, 192);
    fire(&mut l, "FIRE8", 28, 384, 64, 256, 256);
    l.push(LayerDesc::pool("POOL8", 2, 2, 28, 28, 512));
    fire(&mut l, "FIRE9", 14, 512, 64, 256, 256);
    l.push(LayerDesc::pointwise("CONV10", 14, 14, 512, 1000));
    Network { name: "SqueezeNet".into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure() {
        let net = squeezenet();
        assert_eq!(net.layers.iter().filter(|l| l.name.ends_with("_SQ")).count(), 8);
        let g = net.total_macs() as f64 / 1e9;
        assert!((0.7..1.0).contains(&g), "got {g} GMAC");
    }
}
