//! TinyCNN: the end-to-end model (mirrors `python/compile/model.py`
//! `TINYCNN_LAYERS` — the AOT artifact `tinycnn.hlo.txt` computes exactly
//! this network). Used by the e2e inference example, the coordinator
//! pipeline and the sim-vs-HLO verification.

use super::layer::{LayerDesc, Network};
use super::runner::{random_input_dims, FusedNet, NetWeights};
use crate::tensor::{Tensor3, Tensor4};

/// Input dims of TinyCNN.
pub const IN_H: usize = 16;
pub const IN_W: usize = 16;
pub const IN_C: usize = 4;
/// Classes.
pub const CLASSES: usize = 10;

/// The network descriptor (valid padding everywhere — matches python).
pub fn tinycnn() -> Network {
    let layers = vec![
        LayerDesc::conv("conv1", 3, 1, 0, 16, 16, 4, 8),
        LayerDesc::conv("conv2", 3, 2, 0, 14, 14, 8, 16),
        LayerDesc::pointwise("conv3", 6, 6, 16, 24),
        LayerDesc::conv("conv4", 3, 1, 0, 6, 6, 24, 32),
        LayerDesc::fc("fc", 4 * 4 * 32, 10),
    ];
    Network { name: "TinyCNN".into(), layers }
}

/// A full set of TinyCNN weights in code/sign form.
#[derive(Clone, Debug)]
pub struct TinyCnnWeights {
    /// `[K, kh, kw, C]` code tensors for conv1/2/4; 1×1 and fc stored as
    /// `[K, 1, 1, C]`.
    pub codes: Vec<Tensor4>,
    pub signs: Vec<Tensor4>,
}

impl TinyCnnWeights {
    /// Weight tensor shapes in forward order (matches
    /// `model.tinycnn_weight_shapes()` on the python side).
    pub fn shapes() -> Vec<(usize, usize, usize, usize)> {
        vec![
            (8, 3, 3, 4),
            (16, 3, 3, 8),
            (24, 1, 1, 16),
            (32, 3, 3, 24),
            (10, 1, 1, 512),
        ]
    }

    /// Random plausible weights: mostly small codes, ~8% exact zeros —
    /// the same distribution the python test-vector generator uses.
    /// Delegates to the generic [`NetWeights`] generator, which
    /// reproduces the original TinyCNN stream tensor-for-tensor.
    pub fn random(seed: u64) -> Self {
        Self::from_net_weights(NetWeights::random(&tinycnn(), seed))
    }

    /// Re-shape generic [`NetWeights`] (for the TinyCNN network) into
    /// the per-layer code/sign vectors the AOT artifact call expects —
    /// the single seed→weights source of truth for both backends.
    pub fn from_net_weights(nw: NetWeights) -> Self {
        let mut codes = Vec::new();
        let mut signs = Vec::new();
        for pair in nw.layers.into_iter().flatten() {
            codes.push(pair.0);
            signs.push(pair.1);
        }
        TinyCnnWeights { codes, signs }
    }

    /// Borrow these weights as a generic [`NetWeights`] (clones the
    /// tensors — use once at engine construction, not per request).
    pub fn to_net_weights(&self) -> NetWeights {
        NetWeights {
            layers: self
                .codes
                .iter()
                .zip(&self.signs)
                .map(|(c, s)| Some((c.clone(), s.clone())))
                .collect(),
        }
    }
}

/// TinyCNN weights pre-fused for `dataflow::engine`: since the generic
/// graph-executor refactor this is just the generic [`FusedNet`]
/// (layer-aligned, pools `None` — TinyCNN has none).
pub type FusedTinyCnn = FusedNet;

impl TinyCnnWeights {
    /// Fuse every layer's (codes, signs) pair into engine row indices.
    pub fn fuse(&self) -> FusedTinyCnn {
        self.to_net_weights().fuse()
    }
}

/// Random input codes (log-quantized image).
pub fn random_input(seed: u64) -> Tensor3 {
    random_input_dims(IN_H, IN_W, IN_C, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains() {
        tinycnn().validate_chaining().unwrap();
    }

    #[test]
    fn macs_about_29k_plus_head() {
        let net = tinycnn();
        // conv1 14²·9·4·8 + conv2 6²·9·8·16 + conv3 36·16·24 + conv4 4²·9·24·32 + fc 5120
        let expect = 14 * 14 * 9 * 4 * 8 + 36 * 9 * 8 * 16 + 36 * 16 * 24
            + 16 * 9 * 24 * 32 + 512 * 10;
        assert_eq!(net.total_macs(), expect as u64);
    }

    #[test]
    fn weight_shapes_match_python() {
        let w = TinyCnnWeights::random(0);
        assert_eq!(w.codes.len(), 5);
        assert_eq!(w.codes[0].k, 8);
        assert_eq!(w.codes[4].c, 512);
        // deterministic per seed
        let w2 = TinyCnnWeights::random(0);
        assert_eq!(w.codes[1].data, w2.codes[1].data);
    }

    #[test]
    fn fc_matches_flatten_of_conv4() {
        let net = tinycnn();
        let conv4 = &net.layers[3];
        let (ho, wo) = conv4.out_dims();
        assert_eq!(ho * wo * conv4.cout, 512);
    }
}
