//! VGG-16 [2] convolutional workload (ImageNet dims, 224×224×3).
//! The 13 conv layers of Table 3 plus the interleaved max-pools.

use super::layer::{LayerDesc, Network};

/// Build VGG-16 (conv layers + pools; FC head excluded, matching the
/// paper's Table 3 / Fig. 19 which evaluate the conv stack).
pub fn vgg16() -> Network {
    vgg16_scaled("VGG16", 224, &[64, 128, 256, 512, 512])
}

/// Scaled-down VGG-16 shape profile (same 13-conv/4-pool topology) for
/// fast end-to-end execution tests.
pub fn vgg16_test() -> Network {
    vgg16_scaled("VGG16-test", 32, &[4, 8, 8, 16, 16])
}

/// VGG topology generator: five stages of (2,2,3,3,3) 3×3 convs with a
/// 2×2 max-pool between stages; dims chain-propagated from `hw0`.
fn vgg16_scaled(name: &str, hw0: usize, widths: &[usize; 5]) -> Network {
    let stage_convs = [2usize, 2, 3, 3, 3];
    let mut l = Vec::new();
    let mut hw = hw0;
    let mut cin = 3;
    for (si, (&n, &cout)) in stage_convs.iter().zip(widths).enumerate() {
        for ci in 0..n {
            l.push(LayerDesc::conv(
                &format!("CONV{}_{}", si + 1, ci + 1),
                3, 1, 1, hw, hw, cin, cout,
            ));
            cin = cout;
        }
        if si < 4 {
            l.push(LayerDesc::pool(&format!("POOL{}", si + 1), 2, 2, hw, hw, cout));
            hw /= 2;
        }
    }
    Network { name: name.into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains() {
        vgg16().validate_chaining().unwrap();
        vgg16_test().validate_chaining().unwrap();
    }

    #[test]
    fn thirteen_conv_layers() {
        assert_eq!(vgg16().compute_layers().count(), 13);
        assert_eq!(vgg16_test().compute_layers().count(), 13);
    }

    #[test]
    fn total_macs_about_15_3_gmac() {
        // VGG16 conv stack ≈ 15.3 GMAC (literature value)
        let g = vgg16().total_macs() as f64 / 1e9;
        assert!((15.0..15.7).contains(&g), "got {g} GMAC");
    }

    #[test]
    fn conv1_2_is_the_biggest_layer() {
        let net = vgg16();
        let c12 = net.layers.iter().find(|l| l.name == "CONV1_2").unwrap();
        assert_eq!(c12.macs(), 1_849_688_064); // 224²·9·64·64
    }

    #[test]
    fn test_profile_is_tiny_but_isomorphic() {
        let (full, small) = (vgg16(), vgg16_test());
        assert_eq!(full.layers.len(), small.layers.len());
        for (a, b) in full.layers.iter().zip(&small.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kernel(), b.kernel());
        }
        assert!(small.total_macs() < full.total_macs() / 1000);
    }
}
