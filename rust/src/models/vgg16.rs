//! VGG-16 [2] convolutional workload (ImageNet dims, 224×224×3).
//! The 13 conv layers of Table 3 plus the interleaved max-pools.

use super::layer::{LayerDesc, Network};

/// Build VGG-16 (conv layers + pools; FC head excluded, matching the
/// paper's Table 3 / Fig. 19 which evaluate the conv stack).
pub fn vgg16() -> Network {
    let mut l = Vec::new();
    let c = |name: &str, hw: usize, cin: usize, cout: usize| {
        LayerDesc::conv(name, 3, 1, 1, hw, hw, cin, cout)
    };
    l.push(c("CONV1_1", 224, 3, 64));
    l.push(c("CONV1_2", 224, 64, 64));
    l.push(LayerDesc::pool("POOL1", 2, 2, 224, 224, 64));
    l.push(c("CONV2_1", 112, 64, 128));
    l.push(c("CONV2_2", 112, 128, 128));
    l.push(LayerDesc::pool("POOL2", 2, 2, 112, 112, 128));
    l.push(c("CONV3_1", 56, 128, 256));
    l.push(c("CONV3_2", 56, 256, 256));
    l.push(c("CONV3_3", 56, 256, 256));
    l.push(LayerDesc::pool("POOL3", 2, 2, 56, 56, 256));
    l.push(c("CONV4_1", 28, 256, 512));
    l.push(c("CONV4_2", 28, 512, 512));
    l.push(c("CONV4_3", 28, 512, 512));
    l.push(LayerDesc::pool("POOL4", 2, 2, 28, 28, 512));
    l.push(c("CONV5_1", 14, 512, 512));
    l.push(c("CONV5_2", 14, 512, 512));
    l.push(c("CONV5_3", 14, 512, 512));
    Network { name: "VGG16".into(), layers: l }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains() {
        vgg16().validate_chaining().unwrap();
    }

    #[test]
    fn thirteen_conv_layers() {
        assert_eq!(vgg16().compute_layers().count(), 13);
    }

    #[test]
    fn total_macs_about_15_3_gmac() {
        // VGG16 conv stack ≈ 15.3 GMAC (literature value)
        let g = vgg16().total_macs() as f64 / 1e9;
        assert!((15.0..15.7).contains(&g), "got {g} GMAC");
    }

    #[test]
    fn conv1_2_is_the_biggest_layer() {
        let net = vgg16();
        let c12 = net.layers.iter().find(|l| l.name == "CONV1_2").unwrap();
        assert_eq!(c12.macs(), 1_849_688_064); // 224²·9·64·64
    }
}
