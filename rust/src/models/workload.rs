//! Workload and request generation for the serving/benchmark harness.

use super::alexnet::alexnet;
use super::layer::Network;
use super::mobilenet_v1::mobilenet_v1;
use super::resnet34::resnet34;
use super::squeezenet::squeezenet;
use super::tinycnn::tinycnn;
use super::vgg16::vgg16;
use crate::util::prng::SplitMix64;

/// All networks in the zoo by name.
pub fn by_name(name: &str) -> Option<Network> {
    match name.to_ascii_lowercase().as_str() {
        "vgg16" => Some(vgg16()),
        "mobilenet" | "mobilenetv1" | "mobilenet_v1" => Some(mobilenet_v1()),
        "resnet34" | "resnet-34" => Some(resnet34()),
        "squeezenet" => Some(squeezenet()),
        "alexnet" => Some(alexnet()),
        "tinycnn" => Some(tinycnn()),
        _ => None,
    }
}

/// The three networks of Fig. 19 / Fig. 20.
pub fn fig19_nets() -> Vec<Network> {
    vec![vgg16(), mobilenet_v1(), resnet34()]
}

/// An inference request against the serving pipeline.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset in microseconds from stream start.
    pub arrival_us: u64,
    /// Input seed (the server synthesizes the quantized image from it).
    pub seed: u64,
}

/// Poisson-ish request stream generator (exponential inter-arrivals).
pub struct RequestStream {
    rng: SplitMix64,
    next_id: u64,
    clock_us: u64,
    /// Mean inter-arrival gap in microseconds.
    pub mean_gap_us: f64,
}

impl RequestStream {
    pub fn new(seed: u64, rate_per_sec: f64) -> Self {
        RequestStream {
            rng: SplitMix64::new(seed),
            next_id: 0,
            clock_us: 0,
            mean_gap_us: 1e6 / rate_per_sec.max(1e-9),
        }
    }
}

impl Iterator for RequestStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        let u = self.rng.f64().max(1e-12);
        let gap = (-u.ln() * self.mean_gap_us) as u64;
        self.clock_us += gap;
        let r = Request {
            id: self.next_id,
            arrival_us: self.clock_us,
            seed: self.rng.next_u64(),
        };
        self.next_id += 1;
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_lookup() {
        for n in ["vgg16", "mobilenet", "resnet34", "squeezenet", "alexnet", "tinycnn"] {
            assert!(by_name(n).is_some(), "{n} missing");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn request_stream_rate() {
        let reqs: Vec<_> = RequestStream::new(1, 1000.0).take(5000).collect();
        let span_s = reqs.last().unwrap().arrival_us as f64 / 1e6;
        let rate = 5000.0 / span_s;
        assert!((800.0..1200.0).contains(&rate), "rate {rate}");
        // ids increase, arrivals non-decreasing
        for w in reqs.windows(2) {
            assert!(w[1].id == w[0].id + 1);
            assert!(w[1].arrival_us >= w[0].arrival_us);
        }
    }
}
