//! Workload and request generation for the serving/benchmark harness.

use super::alexnet::{alexnet, alexnet_test};
use super::layer::Network;
use super::mobilenet_v1::{mobilenet_v1, mobilenet_v1_test};
use super::resnet34::{resnet34, resnet34_test};
use super::squeezenet::{squeezenet, squeezenet_test};
use super::tinycnn::tinycnn;
use super::vgg16::{vgg16, vgg16_test};
use crate::util::prng::SplitMix64;

/// Canonical zoo model names (full-size profiles).
pub const ZOO_NAMES: [&str; 6] =
    ["tinycnn", "alexnet", "vgg16", "resnet34", "mobilenet_v1", "squeezenet"];

/// Parse a zoo model name (with alias and `-test`/`_test` suffix
/// handling) into its canonical base display name + test flag. Cheap —
/// no `Network` is built.
fn parse_name(name: &str) -> Option<(&'static str, bool)> {
    let lower = name.to_ascii_lowercase();
    let (base, test) = if let Some(b) = lower.strip_suffix("-test") {
        (b, true)
    } else if let Some(b) = lower.strip_suffix("_test") {
        (b, true)
    } else {
        (lower.as_str(), false)
    };
    let canonical = match base {
        "vgg16" => "VGG16",
        "mobilenet" | "mobilenetv1" | "mobilenet_v1" => "MobileNetV1",
        "resnet34" | "resnet-34" => "ResNet34",
        "squeezenet" => "SqueezeNet",
        "alexnet" => "AlexNet",
        "tinycnn" => "TinyCNN",
        _ => return None,
    };
    // TinyCNN is its own test profile
    Some((canonical, test && canonical != "TinyCNN"))
}

/// Canonical display name for a zoo model name (e.g. `VGG16`,
/// `AlexNet-test`), without building the network — alias/case/suffix
/// variants all map to one spelling, itself accepted by [`by_name`].
pub fn canonical_name(name: &str) -> Option<String> {
    parse_name(name).map(|(base, test)| {
        if test {
            format!("{base}-test")
        } else {
            base.to_string()
        }
    })
}

/// All networks in the zoo by name. A `-test`/`_test` suffix selects the
/// scaled-down shape profile (same topology, minutes → milliseconds) —
/// e.g. `vgg16-test`; TinyCNN is its own test profile.
pub fn by_name(name: &str) -> Option<Network> {
    let (base, test) = parse_name(name)?;
    let net = match (base, test) {
        ("VGG16", false) => vgg16(),
        ("VGG16", true) => vgg16_test(),
        ("MobileNetV1", false) => mobilenet_v1(),
        ("MobileNetV1", true) => mobilenet_v1_test(),
        ("ResNet34", false) => resnet34(),
        ("ResNet34", true) => resnet34_test(),
        ("SqueezeNet", false) => squeezenet(),
        ("SqueezeNet", true) => squeezenet_test(),
        ("AlexNet", false) => alexnet(),
        ("AlexNet", true) => alexnet_test(),
        ("TinyCNN", _) => tinycnn(),
        _ => unreachable!("parse_name returned an unknown canonical base"),
    };
    Some(net)
}

/// The scaled-down test profile of a zoo model (TinyCNN is already tiny).
pub fn test_profile(name: &str) -> Option<Network> {
    match name.to_ascii_lowercase().as_str() {
        "tinycnn" => by_name("tinycnn"),
        other => by_name(&format!("{other}-test")),
    }
}

/// The three networks of Fig. 19 / Fig. 20.
pub fn fig19_nets() -> Vec<Network> {
    vec![vgg16(), mobilenet_v1(), resnet34()]
}

/// An inference request against the serving pipeline.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset in microseconds from stream start.
    pub arrival_us: u64,
    /// Input seed (the server synthesizes the quantized image from it).
    pub seed: u64,
}

/// Poisson-ish request stream generator (exponential inter-arrivals).
pub struct RequestStream {
    rng: SplitMix64,
    next_id: u64,
    clock_us: u64,
    /// Mean inter-arrival gap in microseconds.
    pub mean_gap_us: f64,
}

impl RequestStream {
    pub fn new(seed: u64, rate_per_sec: f64) -> Self {
        RequestStream {
            rng: SplitMix64::new(seed),
            next_id: 0,
            clock_us: 0,
            mean_gap_us: 1e6 / rate_per_sec.max(1e-9),
        }
    }
}

impl Iterator for RequestStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        let u = self.rng.f64().max(1e-12);
        let gap = (-u.ln() * self.mean_gap_us) as u64;
        self.clock_us += gap;
        let r = Request {
            id: self.next_id,
            arrival_us: self.clock_us,
            seed: self.rng.next_u64(),
        };
        self.next_id += 1;
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_lookup() {
        for n in ["vgg16", "mobilenet", "resnet34", "squeezenet", "alexnet", "tinycnn"] {
            assert!(by_name(n).is_some(), "{n} missing");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn canonical_names_match_network_names() {
        for n in [
            "vgg16", "VGG16", "vgg16-test", "mobilenet", "mobilenet_v1_test",
            "resnet-34", "squeezenet_test", "alexnet", "tinycnn", "TINYCNN-test",
        ] {
            let canon = canonical_name(n).unwrap_or_else(|| panic!("{n}"));
            assert_eq!(canon, by_name(n).unwrap().name, "{n}");
            // canonical form is itself resolvable and a fixed point
            assert_eq!(canonical_name(&canon), Some(canon.clone()), "{n}");
        }
        assert!(canonical_name("nope").is_none());
    }

    #[test]
    fn test_profiles_resolve_for_whole_zoo() {
        for n in ZOO_NAMES {
            let full = by_name(n).unwrap();
            let small = test_profile(n).unwrap();
            assert_eq!(full.layers.len(), small.layers.len(), "{n}");
            // suffix spelling variants both resolve
            if n != "tinycnn" {
                assert!(by_name(&format!("{n}-test")).is_some(), "{n}-test");
                assert!(by_name(&format!("{n}_test")).is_some(), "{n}_test");
                assert!(small.total_macs() < full.total_macs(), "{n} not scaled");
            }
        }
    }

    #[test]
    fn request_stream_rate() {
        let reqs: Vec<_> = RequestStream::new(1, 1000.0).take(5000).collect();
        let span_s = reqs.last().unwrap().arrival_us as f64 / 1e6;
        let rate = 5000.0 / span_s;
        assert!((800.0..1200.0).contains(&rate), "rate {rate}");
        // ids increase, arrivals non-decreasing
        for w in reqs.windows(2) {
            assert!(w[1].id == w[0].id + 1);
            assert!(w[1].arrival_us >= w[0].arrival_us);
        }
    }
}
