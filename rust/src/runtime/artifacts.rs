//! Artifact manifest: the registry `python/compile/aot.py` writes next to
//! the HLO files. Plain line-based format (no serde offline):
//!
//! ```text
//! artifact tinycnn tinycnn.hlo.txt
//! in a_code s32 16,16,4
//! out logits s32 10
//! end
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One tensor binding (name, dtype, shape).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT artifact: HLO file + typed signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn parse_tensor(line: &str) -> Result<TensorSpec> {
    let mut it = line.split_whitespace();
    let _tag = it.next();
    let name = it.next().context("tensor name missing")?.to_string();
    let dtype = it.next().context("tensor dtype missing")?.to_string();
    let dims_s = it.next().context("tensor dims missing")?;
    let dims = dims_s
        .split(',')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec { name, dtype, dims })
}

impl ArtifactManifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated for testing).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut artifacts = BTreeMap::new();
        let mut cur: Option<ArtifactSpec> = None;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let err = || format!("manifest line {}: `{line}`", ln + 1);
            if let Some(rest) = line.strip_prefix("artifact ") {
                if cur.is_some() {
                    bail!("{}: artifact before previous `end`", err());
                }
                let mut it = rest.split_whitespace();
                let name = it.next().with_context(err)?.to_string();
                let file = it.next().with_context(err)?;
                cur = Some(ArtifactSpec {
                    name,
                    hlo_path: dir.join(file),
                    inputs: vec![],
                    outputs: vec![],
                });
            } else if line.starts_with("in ") {
                cur.as_mut().with_context(err)?.inputs.push(parse_tensor(line)?);
            } else if line.starts_with("out ") {
                cur.as_mut().with_context(err)?.outputs.push(parse_tensor(line)?);
            } else if line == "end" {
                let a = cur.take().with_context(err)?;
                artifacts.insert(a.name.clone(), a);
            } else {
                bail!("{}: unknown directive", err());
            }
        }
        if cur.is_some() {
            bail!("manifest truncated: missing final `end`");
        }
        Ok(ArtifactManifest { artifacts, dir })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact `{name}` not in manifest"))
    }

    /// Default artifact directory: `$NEUROMAX_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("NEUROMAX_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact conv conv.hlo.txt
in a_code s32 18,18,8
in w_code s32 16,3,3,8
out psum s32 16,16,16
end
artifact pp pp.hlo.txt
in psum s32 4
out code s32 4
end
";

    #[test]
    fn parses_two_artifacts() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let c = m.get("conv").unwrap();
        assert_eq!(c.inputs.len(), 2);
        assert_eq!(c.inputs[0].dims, vec![18, 18, 8]);
        assert_eq!(c.inputs[0].elements(), 18 * 18 * 8);
        assert_eq!(c.outputs[0].dims, vec![16, 16, 16]);
        assert_eq!(c.hlo_path, PathBuf::from("/x/conv.hlo.txt"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(ArtifactManifest::parse("bogus line", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse("artifact a f\nin x s32 2", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse("in x s32 2\nend", PathBuf::new()).is_err());
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::new()).unwrap();
        assert!(m.get("nope").is_err());
    }
}
