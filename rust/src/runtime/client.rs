//! The PJRT CPU client wrapper: compile-once, execute-many. One compiled
//! executable per artifact, cached in the runtime.

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::artifacts::{ArtifactManifest, ArtifactSpec};

/// A loaded, compiled artifact.
pub struct CompiledArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledArtifact {
    /// Build the shaped literal for input slot `idx`.
    pub fn literal_for(&self, idx: usize, data: &[i32]) -> Result<xla::Literal> {
        let ts = &self.spec.inputs[idx];
        anyhow::ensure!(
            ts.elements() == data.len(),
            "{}: input `{}` expects {} elements, got {}",
            self.spec.name, ts.name, ts.elements(), data.len()
        );
        let dims: Vec<i64> = ts.dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// Execute with pre-built literals (§Perf optimization 4: callers with
    /// static inputs — e.g. the weight tensors of a serving session —
    /// build them once and reuse).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Vec<i32>>> {
        anyhow::ensure!(
            literals.len() == self.spec.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            literals.len()
        );
        let result = self.exe.execute::<xla::Literal>(literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.spec.name, self.spec.outputs.len(), parts.len()
        );
        let mut outs = Vec::with_capacity(parts.len());
        for (ts, lit) in self.spec.outputs.iter().zip(parts) {
            let v = lit.to_vec::<i32>().with_context(|| {
                format!("{}: output `{}` not s32", self.spec.name, ts.name)
            })?;
            anyhow::ensure!(v.len() == ts.elements(), "output size mismatch");
            outs.push(v);
        }
        Ok(outs)
    }

    /// Execute with int32 tensors (flattened row-major, matching the
    /// manifest shapes). Returns flattened int32 outputs.
    pub fn run_i32(&self, inputs: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let literals = inputs
            .iter()
            .enumerate()
            .map(|(i, d)| self.literal_for(i, d))
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(&literals)
    }
}

/// The runtime: a PJRT CPU client plus compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: HashMap<String, CompiledArtifact>,
}

impl Runtime {
    /// Create from an artifact directory (see `ArtifactManifest`).
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    /// Create from the default artifact dir ($NEUROMAX_ARTIFACTS or ./artifacts).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(ArtifactManifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Compile (or fetch the cached) artifact.
    pub fn load(&mut self, name: &str) -> Result<&CompiledArtifact> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.get(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", spec.hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact `{name}`"))?;
            self.cache.insert(name.to_string(), CompiledArtifact { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// One-shot helper: load + run.
    pub fn run_i32(&mut self, name: &str, inputs: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        self.load(name)?;
        self.cache[name].run_i32(inputs)
    }
}
