//! Sim-only fallback for the PJRT runtime (compiled when the `pjrt`
//! feature is off — the default, since the `xla` crate needs a networked
//! build). Mirrors the API surface of `client.rs`: manifest handling works
//! (it is plain text), every execution entry point fails with a clear
//! error. `Backend::Sim` never touches this module.

use std::path::Path;

use anyhow::{bail, Result};

use super::artifacts::{ArtifactManifest, ArtifactSpec};
use super::NO_PJRT_MSG;

/// Stub stand-in for a compiled artifact (never constructed: `load`
/// fails first).
pub struct CompiledArtifact {
    pub spec: ArtifactSpec,
}

impl CompiledArtifact {
    pub fn run_i32(&self, _inputs: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        bail!(NO_PJRT_MSG)
    }
}

/// The runtime stub: manifest only, no PJRT client.
pub struct Runtime {
    manifest: ArtifactManifest,
}

impl Runtime {
    /// Create from an artifact directory (manifest parsing still works).
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(&dir)?;
        Ok(Runtime { manifest })
    }

    /// Create from the default artifact dir ($NEUROMAX_ARTIFACTS or ./artifacts).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(ArtifactManifest::default_dir())
    }

    pub fn platform(&self) -> String {
        "sim-only (pjrt feature off)".to_string()
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Always fails: compiling artifacts needs the PJRT client.
    pub fn load(&mut self, name: &str) -> Result<&CompiledArtifact> {
        let _ = self.manifest.get(name)?;
        bail!("artifact `{name}`: {NO_PJRT_MSG}")
    }

    pub fn run_i32(&mut self, name: &str, _inputs: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let _ = self.manifest.get(name)?;
        bail!("artifact `{name}`: {NO_PJRT_MSG}")
    }
}
