//! Typed execution helpers over the artifact registry: TinyCNN forward
//! and the single-layer conv executables.

use anyhow::Result;

use super::client::Runtime;
use crate::models::tinycnn::TinyCnnWeights;
use crate::tensor::{Tensor3, Tensor4};

/// Flatten weight tensors into the (codes, signs) argument interleaving
/// the `tinycnn` artifact expects: a, w1c, w1s, w2c, w2s, w3c, w3s, w4c,
/// w4s, wfc, wfs.
pub fn tinycnn_args(a: &Tensor3, w: &TinyCnnWeights) -> Vec<Vec<i32>> {
    let mut args = Vec::with_capacity(11);
    args.push(a.data.clone());
    for (c, s) in w.codes.iter().zip(&w.signs) {
        args.push(c.data.clone());
        args.push(s.data.clone());
    }
    args
}

/// Run the full TinyCNN forward pass on the PJRT executable.
pub fn tinycnn_forward(rt: &mut Runtime, a: &Tensor3, w: &TinyCnnWeights) -> Result<Vec<i32>> {
    let outs = rt.run_i32("tinycnn", &tinycnn_args(a, w))?;
    Ok(outs.into_iter().next().unwrap())
}

/// A serving session with resident weights (§Perf optimization 4): the 10
/// weight literals are built once; only the input literal is rebuilt per
/// request.
pub struct TinyCnnSession {
    /// Slot 0 = input (rewritten per call), 1..=10 = weights (resident).
    literals: Vec<xla::Literal>,
}

impl TinyCnnSession {
    pub fn new(rt: &mut Runtime, w: &TinyCnnWeights) -> Result<Self> {
        let art = rt.load("tinycnn")?;
        let mut literals = Vec::with_capacity(11);
        // placeholder input; overwritten on every forward()
        literals.push(art.literal_for(0, &vec![0i32; art.spec.inputs[0].elements()])?);
        for (i, (c, s)) in w.codes.iter().zip(&w.signs).enumerate() {
            literals.push(art.literal_for(1 + 2 * i, &c.data)?);
            literals.push(art.literal_for(2 + 2 * i, &s.data)?);
        }
        Ok(TinyCnnSession { literals })
    }

    pub fn forward(&mut self, rt: &mut Runtime, a: &Tensor3) -> Result<Vec<i32>> {
        let art = rt.load("tinycnn")?;
        self.literals[0] = art.literal_for(0, &a.data)?;
        let outs = art.run_literals(&self.literals)?;
        Ok(outs.into_iter().next().unwrap())
    }
}

/// Run the single-layer 3×3 stride-1 artifact: a[18,18,8] ⊛ w[16,3,3,8].
pub fn conv3x3_s1(rt: &mut Runtime, a: &Tensor3, wc: &Tensor4, ws: &Tensor4) -> Result<Tensor3> {
    let outs = rt.run_i32(
        "logconv3x3_s1",
        &[a.data.clone(), wc.data.clone(), ws.data.clone()],
    )?;
    Ok(Tensor3::from_vec(16, 16, 16, outs.into_iter().next().unwrap()))
}

/// Run the post-processing artifact (ReLU + requant LUT) on psums.
pub fn postprocess(rt: &mut Runtime, psums: &Tensor3) -> Result<Tensor3> {
    let outs = rt.run_i32("postprocess", &[psums.data.clone()])?;
    Ok(Tensor3::from_vec(
        psums.h,
        psums.w,
        psums.c,
        outs.into_iter().next().unwrap(),
    ))
}
