//! Sim-only fallback for the typed PJRT execution helpers (`pjrt` feature
//! off). Same signatures as `exec.rs`; argument marshalling still works
//! (it is xla-free), execution fails with a clear error so callers fall
//! back to `Backend::Sim`.

use anyhow::{bail, Result};

use super::client::Runtime;
use super::NO_PJRT_MSG;
use crate::models::tinycnn::TinyCnnWeights;
use crate::tensor::{Tensor3, Tensor4};

/// Flatten weight tensors into the (codes, signs) argument interleaving
/// the `tinycnn` artifact expects: a, w1c, w1s, w2c, w2s, w3c, w3s, w4c,
/// w4s, wfc, wfs.
pub fn tinycnn_args(a: &Tensor3, w: &TinyCnnWeights) -> Vec<Vec<i32>> {
    let mut args = Vec::with_capacity(11);
    args.push(a.data.clone());
    for (c, s) in w.codes.iter().zip(&w.signs) {
        args.push(c.data.clone());
        args.push(s.data.clone());
    }
    args
}

/// Stub: the TinyCNN forward needs the PJRT executable.
pub fn tinycnn_forward(
    _rt: &mut Runtime,
    _a: &Tensor3,
    _w: &TinyCnnWeights,
) -> Result<Vec<i32>> {
    bail!("tinycnn forward: {NO_PJRT_MSG}")
}

/// Stub serving session (construction fails; `Backend::Sim` is the
/// offline serving path).
pub struct TinyCnnSession {
    _private: (),
}

impl TinyCnnSession {
    pub fn new(_rt: &mut Runtime, _w: &TinyCnnWeights) -> Result<Self> {
        bail!("tinycnn session: {NO_PJRT_MSG}")
    }

    pub fn forward(&mut self, _rt: &mut Runtime, _a: &Tensor3) -> Result<Vec<i32>> {
        bail!("tinycnn session: {NO_PJRT_MSG}")
    }
}

/// Stub: single-layer 3×3 artifact execution.
pub fn conv3x3_s1(
    _rt: &mut Runtime,
    _a: &Tensor3,
    _wc: &Tensor4,
    _ws: &Tensor4,
) -> Result<Tensor3> {
    bail!("conv3x3_s1: {NO_PJRT_MSG}")
}

/// Stub: post-processing artifact execution.
pub fn postprocess(_rt: &mut Runtime, _psums: &Tensor3) -> Result<Tensor3> {
    bail!("postprocess: {NO_PJRT_MSG}")
}
