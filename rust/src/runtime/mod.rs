//! PJRT runtime: loads the AOT-compiled HLO artifacts (built once by
//! `make artifacts` — python never runs on the request path) and executes
//! them on the XLA CPU client from the rust hot path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifacts;
pub mod client;
pub mod exec;
pub mod verify;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use client::Runtime;
