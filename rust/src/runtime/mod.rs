//! PJRT runtime: loads the AOT-compiled HLO artifacts (built once by
//! `make artifacts` — python never runs on the request path) and executes
//! them on the XLA CPU client from the rust hot path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` dependency is gated behind the (default-off) `pjrt` feature:
//! without it, `client`/`exec` are API-identical stubs whose execution
//! entry points fail with a clear error, and the simulator
//! (`Backend::Sim`, `dataflow::engine`) is the serving path.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod exec;
#[cfg(not(feature = "pjrt"))]
#[path = "exec_stub.rs"]
pub mod exec;
pub mod verify;

#[cfg(not(feature = "pjrt"))]
pub(crate) const NO_PJRT_MSG: &str =
    "PJRT support not compiled in (enable the `pjrt` feature and add the \
     `xla` dependency — see rust/Cargo.toml); use the sim backend instead";

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use client::Runtime;
