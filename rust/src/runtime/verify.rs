//! Sim-vs-HLO golden verification: the cycle simulator's functional path
//! and the AOT-compiled JAX/Pallas computation must agree **bit-for-bit**
//! on the same quantized inputs — two independent implementations of the
//! eq. 8 datapath pinning each other down.
//!
//! Since the graph-executor refactor the forwards here are thin wrappers
//! over the model-generic `dataflow::forward` (one routing plan drives
//! both numeric paths for *any* zoo network); the TinyCNN entry points
//! remain because the AOT artifacts, the python test vectors and the
//! serving benches are pinned to them.

use std::sync::OnceLock;

use anyhow::{ensure, Result};

use super::client::Runtime;
use super::exec;
use crate::dataflow::engine::Engine;
use crate::dataflow::exec as fexec;
use crate::dataflow::forward::{
    forward_engine_batch, forward_engine_planned, forward_ref_planned, forward_ref_with,
    ForwardPlan,
};
use crate::models::layer::Network;
use crate::models::runner::{FusedNet, NetWeights};
use crate::models::tinycnn::{self, random_input, FusedTinyCnn, TinyCnnWeights};
use crate::tensor::{Tensor3, Tensor4};

/// Generic reference forward (reference executor numerics): returns the
/// final layer's flattened output — logits for Fc-headed nets.
pub fn forward_ref(net: &Network, w: &NetWeights, x: &Tensor3) -> Vec<i32> {
    crate::dataflow::forward::forward_ref(net, w, x).data
}

/// Generic engine forward (LUT-fused multi-threaded numerics): bit-
/// identical to [`forward_ref`] on the same weights.
pub fn forward_engine(eng: &Engine, net: &Network, fw: &FusedNet, x: &Tensor3) -> Vec<i32> {
    crate::dataflow::forward::forward_engine(eng, net, fw, x).data
}

fn tinycnn_net_plan() -> &'static (Network, ForwardPlan) {
    static NP: OnceLock<(Network, ForwardPlan)> = OnceLock::new();
    NP.get_or_init(|| {
        let net = tinycnn::tinycnn();
        let plan = ForwardPlan::infer(&net).expect("tinycnn routes");
        (net, plan)
    })
}

/// The rust-side functional TinyCNN forward (mirrors
/// `model.tinycnn_forward` in python — conv → ReLU+requant chain, logits
/// left in the psum domain). Wrapper over the generic executor.
pub fn tinycnn_forward_sim(a: &Tensor3, w: &TinyCnnWeights) -> Vec<i32> {
    let (net, plan) = tinycnn_net_plan();
    // borrowed lookup: no per-call weight clones on the reference path
    forward_ref_with(net, plan, |i| Some((&w.codes[i], &w.signs[i])), a).data
}

/// The engine-backed TinyCNN forward (the serving hot path): identical
/// network chain as [`tinycnn_forward_sim`], computed by the LUT-fused,
/// multi-threaded `dataflow::engine` on pre-fused weights. Bit-identical
/// to the reference (pinned by tests here and in
/// `rust/tests/engine_equiv.rs` / `rust/tests/zoo_forward.rs`).
pub fn tinycnn_forward_engine(eng: &Engine, w: &FusedTinyCnn, a: &Tensor3) -> Vec<i32> {
    let (net, plan) = tinycnn_net_plan();
    forward_engine_planned(eng, net, plan, w, a).data
}

/// Batched engine forward: the whole batch executes as one parallel unit
/// (batch elements spread across the worker pool, each on a serial
/// engine), preserving per-element bit-exactness and input order.
pub fn tinycnn_forward_batch(
    eng: &Engine,
    w: &FusedTinyCnn,
    inputs: &[Tensor3],
) -> Vec<Vec<i32>> {
    let (net, plan) = tinycnn_net_plan();
    forward_engine_batch(eng, net, plan, w, inputs)
        .into_iter()
        .map(|t| t.data)
        .collect()
}

/// Verification outcome.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub cases: usize,
    pub elements_compared: u64,
    pub mismatches: u64,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.mismatches == 0
    }
}

/// Verify the TinyCNN forward over `cases` random (input, weight) draws.
pub fn verify_tinycnn(rt: &mut Runtime, cases: usize, seed: u64) -> Result<VerifyReport> {
    let mut rep = VerifyReport { cases, elements_compared: 0, mismatches: 0 };
    for i in 0..cases {
        let a = random_input(seed ^ (i as u64) << 8);
        let w = TinyCnnWeights::random(seed.wrapping_add(i as u64 * 7919));
        let hlo = exec::tinycnn_forward(rt, &a, &w)?;
        let sim = tinycnn_forward_sim(&a, &w);
        ensure!(hlo.len() == sim.len(), "logit length mismatch");
        rep.elements_compared += hlo.len() as u64;
        rep.mismatches += hlo.iter().zip(&sim).filter(|(a, b)| a != b).count() as u64;
    }
    Ok(rep)
}

/// Verify reference vs engine forwards over a zoo network (no PJRT
/// needed): `cases` random weight/input draws, engine at `threads`.
pub fn verify_zoo_model(
    net: &Network,
    cases: usize,
    seed: u64,
    threads: usize,
) -> Result<VerifyReport> {
    let plan = ForwardPlan::infer(net).map_err(anyhow::Error::msg)?;
    let eng = Engine::with_threads_forced(threads);
    let mut rep = VerifyReport { cases, elements_compared: 0, mismatches: 0 };
    for i in 0..cases {
        let w = NetWeights::random(net, seed.wrapping_add(i as u64 * 7919));
        let fused = w.fuse();
        let a = crate::models::runner::random_input_for(net, seed ^ (i as u64) << 8);
        let want = forward_ref_planned(net, &plan, &w, &a);
        let got = forward_engine_planned(&eng, net, &plan, &fused, &a);
        ensure!(want.len() == got.len(), "output length mismatch");
        rep.elements_compared += want.len() as u64;
        rep.mismatches +=
            want.data.iter().zip(&got.data).filter(|(a, b)| a != b).count() as u64;
    }
    Ok(rep)
}

/// Verify the single-layer 3×3 artifact against both the fast functional
/// conv and the hardware-faithful core.
pub fn verify_conv3x3(rt: &mut Runtime, seed: u64) -> Result<VerifyReport> {
    use crate::lns::logquant::ZERO_CODE;
    use crate::util::prng::SplitMix64;
    let mut rng = SplitMix64::new(seed);
    let mut a = Tensor3::new(18, 18, 8);
    for v in a.data.iter_mut() {
        *v = if rng.bool(0.1) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    let mut wc = Tensor4::new(16, 3, 3, 8);
    let mut ws = Tensor4::new(16, 3, 3, 8);
    for v in wc.data.iter_mut() {
        *v = if rng.bool(0.1) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }

    let hlo = exec::conv3x3_s1(rt, &a, &wc, &ws)?;
    let fast = fexec::conv2d(&a, &wc, &ws, 1);
    let mut core = crate::arch::ConvCore::default();
    let (faithful, _) = core.conv3x3(&a, &wc, &ws, 1);

    let mut rep = VerifyReport { cases: 1, elements_compared: 0, mismatches: 0 };
    for ((x, y), z) in hlo.data.iter().zip(&fast.data).zip(&faithful.data) {
        rep.elements_compared += 1;
        if x != y || y != z {
            rep.mismatches += 1;
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_forward_is_deterministic() {
        let a = random_input(1);
        let w = TinyCnnWeights::random(2);
        assert_eq!(tinycnn_forward_sim(&a, &w), tinycnn_forward_sim(&a, &w));
    }

    #[test]
    fn sim_forward_shapes() {
        let a = random_input(3);
        let w = TinyCnnWeights::random(4);
        assert_eq!(tinycnn_forward_sim(&a, &w).len(), 10);
    }

    #[test]
    fn engine_forward_matches_reference_sim() {
        let w = TinyCnnWeights::random(5);
        let fused = w.fuse();
        for threads in [1usize, 4] {
            let eng = Engine::with_threads(threads);
            for seed in 0..4 {
                let a = random_input(seed);
                assert_eq!(
                    tinycnn_forward_engine(&eng, &fused, &a),
                    tinycnn_forward_sim(&a, &w),
                    "threads={threads} seed={seed}"
                );
            }
        }
    }

    #[test]
    fn batch_forward_matches_singles() {
        let w = TinyCnnWeights::random(6);
        let fused = w.fuse();
        let eng = Engine::with_threads(4);
        let inputs: Vec<Tensor3> = (0..7).map(random_input).collect();
        let batch = tinycnn_forward_batch(&eng, &fused, &inputs);
        assert_eq!(batch.len(), inputs.len());
        for (a, got) in inputs.iter().zip(&batch) {
            assert_eq!(got, &tinycnn_forward_engine(&eng, &fused, a));
        }
    }

    #[test]
    fn zoo_verify_reports_zero_mismatches() {
        let net = crate::models::workload::test_profile("alexnet").unwrap();
        let rep = verify_zoo_model(&net, 2, 42, 2).unwrap();
        assert!(rep.ok(), "{} mismatches", rep.mismatches);
        assert!(rep.elements_compared > 0);
    }
}
