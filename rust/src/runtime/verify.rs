//! Sim-vs-HLO golden verification: the cycle simulator's functional path
//! and the AOT-compiled JAX/Pallas computation must agree **bit-for-bit**
//! on the same quantized inputs — two independent implementations of the
//! eq. 8 datapath pinning each other down.

use anyhow::{ensure, Result};

use super::client::Runtime;
use super::exec;
use crate::dataflow::engine::Engine;
use crate::dataflow::exec as fexec;
use crate::models::tinycnn::{random_input, FusedTinyCnn, TinyCnnWeights};
use crate::tensor::{Tensor3, Tensor4};

/// The rust-side functional TinyCNN forward (mirrors
/// `model.tinycnn_forward` in python — conv → ReLU+requant chain, logits
/// left in the psum domain).
pub fn tinycnn_forward_sim(a: &Tensor3, w: &TinyCnnWeights) -> Vec<i32> {
    // conv1: 16×16×4 -> 14×14×8
    let x = fexec::requant(&fexec::conv2d(a, &w.codes[0], &w.signs[0], 1));
    // conv2: 14×14×8 -> 6×6×16 (s2)
    let x = fexec::requant(&fexec::conv2d(&x, &w.codes[1], &w.signs[1], 2));
    // conv3 (1×1): 6×6×16 -> 6×6×24
    let x = fexec::requant(&fexec::pointwise(&x, &w.codes[2], &w.signs[2], 1));
    // conv4: 6×6×24 -> 4×4×32
    let x = fexec::requant(&fexec::conv2d(&x, &w.codes[3], &w.signs[3], 1));
    // fc head: 512 -> 10 (raw psums)
    fexec::fc(&x, &w.codes[4], &w.signs[4])
}

/// The engine-backed TinyCNN forward (the serving hot path): identical
/// network chain as [`tinycnn_forward_sim`], computed by the LUT-fused,
/// multi-threaded `dataflow::engine` on pre-fused weights. Bit-identical
/// to the reference (pinned by tests here and in
/// `rust/tests/engine_equiv.rs`).
pub fn tinycnn_forward_engine(eng: &Engine, w: &FusedTinyCnn, a: &Tensor3) -> Vec<i32> {
    // conv1: 16×16×4 -> 14×14×8
    let x = fexec::requant(&eng.conv2d(a, &w.layers[0], 1));
    // conv2: 14×14×8 -> 6×6×16 (s2)
    let x = fexec::requant(&eng.conv2d(&x, &w.layers[1], 2));
    // conv3 (1×1): 6×6×16 -> 6×6×24
    let x = fexec::requant(&eng.pointwise(&x, &w.layers[2], 1));
    // conv4: 6×6×24 -> 4×4×32
    let x = fexec::requant(&eng.conv2d(&x, &w.layers[3], 1));
    // fc head: 512 -> 10 (raw psums)
    eng.fc(&x, &w.layers[4])
}

/// Batched engine forward: the whole batch executes as one parallel unit
/// (batch elements spread across the worker pool, each on a serial
/// engine), preserving per-element bit-exactness and input order.
pub fn tinycnn_forward_batch(
    eng: &Engine,
    w: &FusedTinyCnn,
    inputs: &[Tensor3],
) -> Vec<Vec<i32>> {
    eng.par_map(inputs, |e, a| tinycnn_forward_engine(e, w, a))
}

/// Verification outcome.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub cases: usize,
    pub elements_compared: u64,
    pub mismatches: u64,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.mismatches == 0
    }
}

/// Verify the TinyCNN forward over `cases` random (input, weight) draws.
pub fn verify_tinycnn(rt: &mut Runtime, cases: usize, seed: u64) -> Result<VerifyReport> {
    let mut rep = VerifyReport { cases, elements_compared: 0, mismatches: 0 };
    for i in 0..cases {
        let a = random_input(seed ^ (i as u64) << 8);
        let w = TinyCnnWeights::random(seed.wrapping_add(i as u64 * 7919));
        let hlo = exec::tinycnn_forward(rt, &a, &w)?;
        let sim = tinycnn_forward_sim(&a, &w);
        ensure!(hlo.len() == sim.len(), "logit length mismatch");
        rep.elements_compared += hlo.len() as u64;
        rep.mismatches += hlo.iter().zip(&sim).filter(|(a, b)| a != b).count() as u64;
    }
    Ok(rep)
}

/// Verify the single-layer 3×3 artifact against both the fast functional
/// conv and the hardware-faithful core.
pub fn verify_conv3x3(rt: &mut Runtime, seed: u64) -> Result<VerifyReport> {
    use crate::lns::logquant::ZERO_CODE;
    use crate::util::prng::SplitMix64;
    let mut rng = SplitMix64::new(seed);
    let mut a = Tensor3::new(18, 18, 8);
    for v in a.data.iter_mut() {
        *v = if rng.bool(0.1) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    let mut wc = Tensor4::new(16, 3, 3, 8);
    let mut ws = Tensor4::new(16, 3, 3, 8);
    for v in wc.data.iter_mut() {
        *v = if rng.bool(0.1) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }

    let hlo = exec::conv3x3_s1(rt, &a, &wc, &ws)?;
    let fast = fexec::conv2d(&a, &wc, &ws, 1);
    let mut core = crate::arch::ConvCore::default();
    let (faithful, _) = core.conv3x3(&a, &wc, &ws, 1);

    let mut rep = VerifyReport { cases: 1, elements_compared: 0, mismatches: 0 };
    for ((x, y), z) in hlo.data.iter().zip(&fast.data).zip(&faithful.data) {
        rep.elements_compared += 1;
        if x != y || y != z {
            rep.mismatches += 1;
        }
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_forward_is_deterministic() {
        let a = random_input(1);
        let w = TinyCnnWeights::random(2);
        assert_eq!(tinycnn_forward_sim(&a, &w), tinycnn_forward_sim(&a, &w));
    }

    #[test]
    fn sim_forward_shapes() {
        let a = random_input(3);
        let w = TinyCnnWeights::random(4);
        assert_eq!(tinycnn_forward_sim(&a, &w).len(), 10);
    }

    #[test]
    fn engine_forward_matches_reference_sim() {
        let w = TinyCnnWeights::random(5);
        let fused = w.fuse();
        for threads in [1usize, 4] {
            let eng = Engine::with_threads(threads);
            for seed in 0..4 {
                let a = random_input(seed);
                assert_eq!(
                    tinycnn_forward_engine(&eng, &fused, &a),
                    tinycnn_forward_sim(&a, &w),
                    "threads={threads} seed={seed}"
                );
            }
        }
    }

    #[test]
    fn batch_forward_matches_singles() {
        let w = TinyCnnWeights::random(6);
        let fused = w.fuse();
        let eng = Engine::with_threads(4);
        let inputs: Vec<Tensor3> = (0..7).map(random_input).collect();
        let batch = tinycnn_forward_batch(&eng, &fused, &inputs);
        assert_eq!(batch.len(), inputs.len());
        for (a, got) in inputs.iter().zip(&batch) {
            assert_eq!(got, &tinycnn_forward_engine(&eng, &fused, a));
        }
    }
}
