//! Energy model (paper §1 / [6] Horowitz ISSCC'14): DDR access ≈ 200× the
//! energy of a MAC; on-chip SRAM ≈ 6×. Absolute joules are calibrated to
//! the paper's 2.727 W total at the measured VGG16 throughput.

use crate::dataflow::LayerPerf;

/// Relative energy units (1.0 = one log-MAC).
pub const E_MAC: f64 = 1.0;
/// On-chip SRAM access (per value).
pub const E_SRAM: f64 = 6.0;
/// Off-chip DDR access (per 16-bit word) — the 200× figure.
pub const E_DDR: f64 = 200.0;

/// Energy of one layer in MAC-equivalents.
pub fn layer_energy_units(p: &LayerPerf) -> f64 {
    let macs = p.macs as f64;
    let sram = (p.traffic.sram_reads + p.traffic.sram_writes) as f64;
    let ddr = p.traffic.ddr_accesses() as f64;
    macs * E_MAC + sram * E_SRAM + ddr * E_DDR
}

/// Energy breakdown for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub mac_units: f64,
    pub sram_units: f64,
    pub ddr_units: f64,
}

impl EnergyBreakdown {
    pub fn of(p: &LayerPerf) -> Self {
        EnergyBreakdown {
            mac_units: p.macs as f64 * E_MAC,
            sram_units: (p.traffic.sram_reads + p.traffic.sram_writes) as f64 * E_SRAM,
            ddr_units: p.traffic.ddr_accesses() as f64 * E_DDR,
        }
    }

    pub fn total(&self) -> f64 {
        self.mac_units + self.sram_units + self.ddr_units
    }

    pub fn ddr_fraction(&self) -> f64 {
        self.ddr_units / self.total().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::GridConfig;
    use crate::dataflow::{analyze, ScheduleOptions};
    use crate::models::layer::LayerDesc;

    #[test]
    fn reuse_keeps_ddr_fraction_low() {
        // The whole point of the dataflow: DDR energy must not dominate.
        let l = LayerDesc::conv("c", 3, 1, 1, 56, 56, 128, 128);
        let p = analyze(&GridConfig::neuromax(), &l, ScheduleOptions::default());
        let e = EnergyBreakdown::of(&p);
        assert!(e.ddr_fraction() < 0.5, "DDR fraction {}", e.ddr_fraction());
    }

    #[test]
    fn energy_scales_with_macs() {
        let g = GridConfig::neuromax();
        let small = analyze(&g, &LayerDesc::conv("s", 3, 1, 1, 14, 14, 64, 64),
                            ScheduleOptions::default());
        let big = analyze(&g, &LayerDesc::conv("b", 3, 1, 1, 28, 28, 64, 64),
                          ScheduleOptions::default());
        assert!(layer_energy_units(&big) > 3.0 * layer_energy_units(&small));
    }
}
