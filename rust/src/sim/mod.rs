//! Simulation accounting: cycle/energy/traffic statistics aggregation and
//! the DDR energy model (Horowitz [6]: a DDR access costs ~200× a MAC).

pub mod energy;
pub mod stats;
pub mod trace;

pub use stats::{LayerReport, NetworkReport};
