//! Per-layer and per-network simulation reports (the data behind Fig. 19,
//! Fig. 20 and Table 3).
//!
//! Wraps `dataflow::schedule::analyze` over every layer of a network and
//! aggregates cycles, utilization, latency, GOPS (paper accounting and
//! physical) and DDR traffic; `neuromax simulate <model>` prints these
//! per layer, and `coordinator::reports` formats the paper tables.

use crate::arch::config::GridConfig;
use crate::dataflow::{analyze, LayerPerf, ScheduleOptions};
use crate::models::layer::Network;
use crate::sim::energy;

/// One layer's report row.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub perf: LayerPerf,
    pub util_total: f64,
    pub util_used: f64,
    pub latency_ms: f64,
    pub gops_paper: f64,
    pub energy_units: f64,
}

/// A whole network's simulation summary.
#[derive(Clone, Debug)]
pub struct NetworkReport {
    pub name: String,
    pub layers: Vec<LayerReport>,
    pub total_cycles: u64,
    pub total_macs: u64,
    pub total_latency_ms: f64,
    /// Unweighted mean utilization over compute layers — the paper's
    /// Fig. 19 "average utilization" accounting.
    pub avg_util: f64,
    /// MAC-weighted (cycle-exact) utilization: total MACs / total lane
    /// slots. The honest throughput number.
    pub util_weighted: f64,
    /// Achieved GOPS (paper accounting: peak × util).
    pub gops_paper: f64,
    pub gops_physical: f64,
    pub energy_units: f64,
}

/// Simulate a network through the analytic scheduler.
pub fn simulate_network(grid: &GridConfig, net: &Network, opt: ScheduleOptions) -> NetworkReport {
    let mut layers = Vec::new();
    let (mut cycles, mut macs, mut energy_units) = (0u64, 0u64, 0f64);
    for l in &net.layers {
        let perf = analyze(grid, l, opt);
        let e = energy::layer_energy_units(&perf);
        cycles += perf.cycles;
        macs += perf.macs;
        energy_units += e;
        layers.push(LayerReport {
            util_total: perf.util_total(grid),
            util_used: perf.util_used(grid),
            latency_ms: perf.latency_ms(grid),
            gops_paper: perf.gops_paper(grid),
            energy_units: e,
            perf,
        });
    }
    // weighted: total MACs over total lane slots of compute layers
    let (mut m, mut s) = (0f64, 0f64);
    // unweighted: mean of per-layer utilizations (Fig. 19 accounting)
    let (mut usum, mut un) = (0f64, 0u32);
    for lr in &layers {
        if lr.perf.macs > 0 {
            m += lr.perf.macs as f64;
            s += lr.perf.cycles as f64 * grid.lanes() as f64;
            usum += lr.util_total;
            un += 1;
        }
    }
    let util_weighted = if s > 0.0 { m / s } else { 0.0 };
    let avg_util = if un > 0 { usum / un as f64 } else { 0.0 };
    NetworkReport {
        name: net.name.clone(),
        total_latency_ms: cycles as f64 / (grid.clock_mhz * 1e3),
        total_cycles: cycles,
        total_macs: macs,
        avg_util,
        util_weighted,
        gops_paper: grid.peak_gops_paper() * avg_util,
        gops_physical: grid.peak_gops_physical() * util_weighted,
        energy_units,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mobilenet_v1::mobilenet_v1, resnet34::resnet34, vgg16::vgg16};

    #[test]
    fn fig19_average_utilizations() {
        // paper: 95% VGG-16, 84% MobileNet v1, 86% ResNet-34. Our stricter
        // accounting charges partial-sector idle rows (as the paper's own
        // §5.1 example does; its Fig. 19 apparently does not), landing a
        // few points lower — measured 86% / 79% / 76%. The *ordering* (VGG
        // highest) and the stride-2 dips are the reproduction target; see
        // EXPERIMENTS.md E5.
        let g = GridConfig::neuromax();
        let opt = ScheduleOptions::default();
        let v = simulate_network(&g, &vgg16(), opt).avg_util;
        let m = simulate_network(&g, &mobilenet_v1(), opt).avg_util;
        let r = simulate_network(&g, &resnet34(), opt).avg_util;
        assert!((0.83..0.97).contains(&v), "VGG {v}");
        assert!((0.72..0.90).contains(&m), "MobileNet {m}");
        assert!((0.70..0.92).contains(&r), "ResNet {r}");
        assert!(v > m && v > r, "VGG should lead: {v} {m} {r}");
    }

    #[test]
    fn fig20_gops_factors() {
        // paper: 307.8 / 281.8 / 268.9 GOPS for VGG / ResNet / MobileNet,
        // an ~85% increase over VWA's 166.3 with 28% fewer (adjusted) PEs.
        // Our stricter utilization gives 279 GOPS → a 68% increase; the
        // who-wins-by-what-factor shape holds (E6).
        let g = GridConfig::neuromax();
        let opt = ScheduleOptions::default();
        let v = simulate_network(&g, &vgg16(), opt).gops_paper;
        assert!((260.0..320.0).contains(&v), "VGG GOPS {v}");
        let vwa_gops = crate::baseline::vwa::simulate(&vgg16()).gops;
        assert!(v / vwa_gops > 1.5, "should beat VWA by >1.5×: {v} vs {vwa_gops}");
    }

    #[test]
    fn vgg_total_latency_near_table3() {
        // Table 3 total: 240.23 ms (conv layers, 200 MHz, high-util model)
        let g = GridConfig::neuromax();
        let rep = simulate_network(
            &g, &vgg16(), ScheduleOptions { filter_packing: true, ..Default::default() });
        let conv_ms: f64 = rep.layers.iter()
            .filter(|l| l.perf.macs > 0)
            .map(|l| l.latency_ms).sum();
        assert!((230.0..270.0).contains(&conv_ms), "total {conv_ms} ms");
    }
}
