//! Cycle traces of the faithful 3×3 pipeline — a textual "waveform" of
//! the Fig. 7/8 dataflow for debugging and documentation (`neuromax`
//! doesn't ship a VCD writer; this is the human-readable equivalent).

use crate::arch::adder_net1::AdderNet1;
use crate::arch::matrix::PeMatrix;
use crate::arch::state_controller as sc;
use crate::tensor::{out_dim, Tensor3, Tensor4};

/// Render the first `max_cycles` column-cycles of a single-channel,
/// single-filter 3×3 convolution: per cycle the input tile window, the 18
/// adder-net-0 psums and the adder-net-1 completions/stores.
pub fn trace_conv3x3(
    a: &Tensor3,
    w_code: &Tensor4,
    w_sign: &Tensor4,
    stride: usize,
    max_cycles: usize,
) -> String {
    assert_eq!(a.c, 1, "trace supports single-channel runs");
    assert_eq!(w_code.k, 1);
    let wo = out_dim(a.w, 3, stride);
    let schedule = sc::conv3x3_schedule(a.h, wo);
    let wb = sc::weight_block(w_code, w_sign, 0, 0);
    let mut matrix = PeMatrix::new();
    let mut net1 = AdderNet1::new(stride);
    let mut cur_sector = usize::MAX;
    let mut out = String::new();
    out.push_str(&format!(
        "trace: {}x{} input, 3x3 stride {}, {} column cycles total\n",
        a.h, a.w, stride, schedule.len()
    ));
    for (t, op) in schedule.iter().enumerate() {
        if t >= max_cycles {
            out.push_str("... (truncated)\n");
            break;
        }
        if op.sector != cur_sector {
            if cur_sector != usize::MAX {
                net1.next_sector();
            }
            cur_sector = op.sector;
        }
        let tile = sc::input_tile(a, 0, op.sector, op.col, stride);
        let o = matrix.process(&tile, &wb);
        let res = net1.process_column(&o, op.last_sector);
        out.push_str(&format!(
            "t={:<3} sector {} col {}  inputs[r0]={:?}\n",
            t + 1,
            op.sector,
            op.col,
            tile[0]
        ));
        out.push_str("      o(r,k): ");
        for (r, row) in o.iter().enumerate() {
            out.push_str(&format!("r{r}:{:?} ", row));
        }
        out.push('\n');
        let done: Vec<String> = res
            .done
            .iter()
            .map(|(rel, v)| {
                let label = match *rel {
                    usize::MAX => "prev+1".to_string(),
                    x if x == usize::MAX - 1 => "prev+0".to_string(),
                    r => format!("row{r}"),
                };
                format!("{label}={v}")
            })
            .collect();
        out.push_str(&format!(
            "      adder-net-1: done [{}] stored {}\n",
            done.join(", "),
            res.stored
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::SplitMix64;

    fn case() -> (Tensor3, Tensor4, Tensor4) {
        let mut rng = SplitMix64::new(1);
        let mut a = Tensor3::new(12, 6, 1);
        for v in a.data.iter_mut() {
            *v = rng.range_i32(-6, 4);
        }
        let mut wc = Tensor4::new(1, 3, 3, 1);
        let mut ws = Tensor4::new(1, 3, 3, 1);
        for v in wc.data.iter_mut() {
            *v = rng.range_i32(-4, 4);
        }
        for v in ws.data.iter_mut() {
            *v = rng.sign();
        }
        (a, wc, ws)
    }

    #[test]
    fn trace_covers_the_paper_example() {
        let (a, wc, ws) = case();
        let t = trace_conv3x3(&a, &wc, &ws, 1, 100);
        // 8 cycles, like Fig. 8
        assert!(t.contains("8 column cycles total"));
        assert!(t.contains("t=1"));
        assert!(t.contains("t=8"));
        assert!(t.contains("stored 2"));
        // boundary completions appear in the second sector
        assert!(t.contains("prev+0"));
    }

    #[test]
    fn truncation_works() {
        let (a, wc, ws) = case();
        let t = trace_conv3x3(&a, &wc, &ws, 1, 3);
        assert!(t.contains("(truncated)"));
        assert!(!t.contains("t=5"));
    }
}
