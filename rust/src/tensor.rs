//! Minimal dense tensors for the simulator (int32 log-code / psum domain).
//!
//! Layouts match the python side: activations `[H, W, C]`, weights
//! `[K, kh, kw, C]`, outputs `[Ho, Wo, K]` — all row-major.

/// 3-D int32 tensor `[H, W, C]` (activations, psum maps).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor3 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i32>,
}

impl Tensor3 {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Tensor3 { h, w, c, data: vec![0; h * w * c] }
    }

    pub fn filled(h: usize, w: usize, c: usize, v: i32) -> Self {
        Tensor3 { h, w, c, data: vec![v; h * w * c] }
    }

    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), h * w * c, "tensor3 size mismatch");
        Tensor3 { h, w, c, data }
    }

    #[inline(always)]
    pub fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        (y * self.w + x) * self.c + ch
    }

    #[inline(always)]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> i32 {
        self.data[self.idx(y, x, ch)]
    }

    #[inline(always)]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: i32) {
        let i = self.idx(y, x, ch);
        self.data[i] = v;
    }

    #[inline(always)]
    pub fn add_wrapping(&mut self, y: usize, x: usize, ch: usize, v: i32) {
        let i = self.idx(y, x, ch);
        self.data[i] = self.data[i].wrapping_add(v);
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Map every element (e.g. post-processing ReLU+requant).
    pub fn map(&self, mut f: impl FnMut(i32) -> i32) -> Tensor3 {
        Tensor3 {
            h: self.h,
            w: self.w,
            c: self.c,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }
}

/// 4-D int32 tensor `[K, kh, kw, C]` (filter banks).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor4 {
    pub k: usize,
    pub kh: usize,
    pub kw: usize,
    pub c: usize,
    pub data: Vec<i32>,
}

impl Tensor4 {
    pub fn new(k: usize, kh: usize, kw: usize, c: usize) -> Self {
        Tensor4 { k, kh, kw, c, data: vec![0; k * kh * kw * c] }
    }

    pub fn from_vec(k: usize, kh: usize, kw: usize, c: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), k * kh * kw * c, "tensor4 size mismatch");
        Tensor4 { k, kh, kw, c, data }
    }

    #[inline(always)]
    pub fn idx(&self, k: usize, dy: usize, dx: usize, ch: usize) -> usize {
        debug_assert!(k < self.k && dy < self.kh && dx < self.kw && ch < self.c);
        ((k * self.kh + dy) * self.kw + dx) * self.c + ch
    }

    #[inline(always)]
    pub fn get(&self, k: usize, dy: usize, dx: usize, ch: usize) -> i32 {
        self.data[self.idx(k, dy, dx, ch)]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Valid-convolution output size (shared shape rule).
pub fn out_dim(size: usize, k: usize, stride: usize) -> usize {
    assert!(size >= k, "input {size} smaller than kernel {k}");
    (size - k) / stride + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t3_indexing_roundtrip() {
        let mut t = Tensor3::new(3, 4, 5);
        t.set(2, 3, 4, 42);
        t.set(0, 0, 0, -7);
        assert_eq!(t.get(2, 3, 4), 42);
        assert_eq!(t.get(0, 0, 0), -7);
        assert_eq!(t.len(), 60);
    }

    #[test]
    fn t3_layout_is_hwc_rowmajor() {
        let mut t = Tensor3::new(2, 2, 2);
        t.set(0, 0, 1, 1);
        t.set(0, 1, 0, 2);
        t.set(1, 0, 0, 3);
        assert_eq!(t.data, vec![0, 1, 2, 0, 3, 0, 0, 0]);
    }

    #[test]
    fn t4_indexing() {
        let mut t = Tensor4::new(2, 3, 3, 4);
        let i = t.idx(1, 2, 2, 3);
        t.data[i] = 9;
        assert_eq!(t.get(1, 2, 2, 3), 9);
        assert_eq!(t.len(), 72);
    }

    #[test]
    fn wrapping_accumulate() {
        let mut t = Tensor3::new(1, 1, 1);
        t.set(0, 0, 0, i32::MAX);
        t.add_wrapping(0, 0, 0, 1);
        assert_eq!(t.get(0, 0, 0), i32::MIN);
    }

    #[test]
    fn out_dims_match_paper_example() {
        // paper §5.1: 12x6 input, 3x3 filter -> 10x4 (s1)
        assert_eq!(out_dim(12, 3, 1), 10);
        assert_eq!(out_dim(6, 3, 1), 4);
        assert_eq!(out_dim(12, 3, 2), 5);
    }

    #[test]
    #[should_panic]
    fn out_dim_rejects_undersized() {
        out_dim(2, 3, 1);
    }
}
