//! Tiny benchmark harness for the `harness = false` bench targets
//! (criterion is unavailable offline). Median-of-runs wall timing with
//! warmup, plus throughput helpers.

use std::time::{Duration, Instant};

/// Result of one measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub runs: usize,
}

impl Measurement {
    pub fn per_iter(&self, iters: u64) -> Duration {
        Duration::from_nanos((self.median.as_nanos() as u64) / iters.max(1))
    }
}

/// Time `f` (which should run its workload `iters` times internally):
/// 1 warmup + `runs` measured repetitions, median reported.
pub fn time<F: FnMut()>(runs: usize, mut f: F) -> Measurement {
    f(); // warmup
    let mut samples: Vec<Duration> = (0..runs.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    samples.sort_unstable();
    Measurement {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        runs: samples.len(),
    }
}

/// Print a standard bench line: name, median, and a derived rate.
pub fn report(name: &str, m: Measurement, units: u64, unit_name: &str) {
    let rate = units as f64 / m.median.as_secs_f64();
    println!(
        "bench {name:40} median {:>12?}  ({:.3e} {unit_name}/s)",
        m.median, rate
    );
}

/// A trivial blackbox to keep the optimizer honest (std::hint::black_box
/// wrapper, centralized in case the toolchain changes).
#[inline]
pub fn blackbox<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One recorded bench result (see [`BenchLog`]).
#[derive(Clone, Debug)]
pub struct BenchEntry {
    pub name: String,
    pub median_ns: u128,
    pub min_ns: u128,
    pub max_ns: u128,
    pub units: u64,
    pub unit: String,
    /// Micro-kernel arch the row measured (`avx2`/`neon`/`scalar`),
    /// empty for rows where the kernel arch is not the variable —
    /// lets scalar-vs-SIMD rows be compared across machines and runs.
    pub arch: String,
}

impl BenchEntry {
    /// Median nanoseconds per unit of work.
    pub fn ns_per_unit(&self) -> f64 {
        self.median_ns as f64 / self.units.max(1) as f64
    }

    /// Units of work per second at the median.
    pub fn units_per_s(&self) -> f64 {
        if self.median_ns == 0 {
            return 0.0;
        }
        self.units as f64 * 1e9 / self.median_ns as f64
    }
}

/// Machine-readable bench sink: records every reported measurement and
/// writes a `BENCH_*.json` file (hand-rolled JSON — serde is unavailable
/// offline) so the perf trajectory can be tracked across PRs.
#[derive(Default)]
pub struct BenchLog {
    pub entries: Vec<BenchEntry>,
}

impl BenchLog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Print the standard bench line AND record it for the JSON report.
    pub fn report(&mut self, name: &str, m: Measurement, units: u64, unit: &str) {
        self.report_arch(name, m, units, unit, "");
    }

    /// [`BenchLog::report`] with an explicit micro-kernel `arch` column
    /// (`avx2`/`neon`/`scalar`) — the GEM scalar-vs-SIMD rows use this
    /// so runs on different machines stay comparable.
    pub fn report_arch(&mut self, name: &str, m: Measurement, units: u64, unit: &str, arch: &str) {
        report(name, m, units, unit);
        self.entries.push(BenchEntry {
            name: name.to_string(),
            median_ns: m.median.as_nanos(),
            min_ns: m.min.as_nanos(),
            max_ns: m.max.as_nanos(),
            units,
            unit: unit.to_string(),
            arch: arch.to_string(),
        });
    }

    /// Serialize to JSON text (schema `neuromax-bench/v1`).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"schema\": \"neuromax-bench/v1\",\n  \"benches\": [");
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"median_ns\": {}, \"min_ns\": {}, \
                 \"max_ns\": {}, \"units\": {}, \"unit\": \"{}\", \"arch\": \"{}\", \
                 \"ns_per_unit\": {:.4}, \"units_per_s\": {:.1}}}",
                json_escape(&e.name),
                e.median_ns,
                e.min_ns,
                e.max_ns,
                e.units,
                json_escape(&e.unit),
                json_escape(&e.arch),
                e.ns_per_unit(),
                e.units_per_s(),
            ));
        }
        s.push_str("\n  ]\n}\n");
        s
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let m = time(3, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(blackbox(i));
            }
            blackbox(s);
        });
        assert!(m.min <= m.median && m.median <= m.max);
        assert!(m.median.as_nanos() > 0);
        assert_eq!(m.runs, 3);
    }

    #[test]
    fn per_iter_divides() {
        let m = Measurement {
            median: Duration::from_micros(1000),
            min: Duration::from_micros(900),
            max: Duration::from_micros(1100),
            runs: 3,
        };
        assert_eq!(m.per_iter(1000), Duration::from_micros(1));
    }

    #[test]
    fn bench_log_serializes_valid_json() {
        let mut log = BenchLog::new();
        let m = Measurement {
            median: Duration::from_nanos(1500),
            min: Duration::from_nanos(1000),
            max: Duration::from_nanos(2000),
            runs: 3,
        };
        log.report("L3b \"quoted\" name", m, 3, "MAC");
        log.report_arch("GEM conv gemm", m, 3, "MAC", "avx2");
        let j = log.to_json();
        assert!(j.contains("\"schema\": \"neuromax-bench/v1\""), "{j}");
        assert!(j.contains("\\\"quoted\\\""), "{j}");
        assert!(j.contains("\"median_ns\": 1500"), "{j}");
        assert!(j.contains("\"ns_per_unit\": 500.0000"), "{j}");
        // arch column: explicit on report_arch rows, empty otherwise
        assert!(j.contains("\"arch\": \"avx2\""), "{j}");
        assert!(j.contains("\"arch\": \"\""), "{j}");
        // balanced braces/brackets (cheap well-formedness check)
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn bench_entry_rates() {
        let e = BenchEntry {
            name: "x".into(),
            median_ns: 2_000_000_000,
            min_ns: 1,
            max_ns: 3,
            units: 4,
            unit: "op".into(),
            arch: String::new(),
        };
        assert!((e.ns_per_unit() - 5e8).abs() < 1e-6);
        assert!((e.units_per_s() - 2.0).abs() < 1e-9);
    }
}
