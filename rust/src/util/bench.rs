//! Tiny benchmark harness for the `harness = false` bench targets
//! (criterion is unavailable offline). Median-of-runs wall timing with
//! warmup, plus throughput helpers.

use std::time::{Duration, Instant};

/// Result of one measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub runs: usize,
}

impl Measurement {
    pub fn per_iter(&self, iters: u64) -> Duration {
        Duration::from_nanos((self.median.as_nanos() as u64) / iters.max(1))
    }
}

/// Time `f` (which should run its workload `iters` times internally):
/// 1 warmup + `runs` measured repetitions, median reported.
pub fn time<F: FnMut()>(runs: usize, mut f: F) -> Measurement {
    f(); // warmup
    let mut samples: Vec<Duration> = (0..runs.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    samples.sort_unstable();
    Measurement {
        median: samples[samples.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        runs: samples.len(),
    }
}

/// Print a standard bench line: name, median, and a derived rate.
pub fn report(name: &str, m: Measurement, units: u64, unit_name: &str) {
    let rate = units as f64 / m.median.as_secs_f64();
    println!(
        "bench {name:40} median {:>12?}  ({:.3e} {unit_name}/s)",
        m.median, rate
    );
}

/// A trivial blackbox to keep the optimizer honest (std::hint::black_box
/// wrapper, centralized in case the toolchain changes).
#[inline]
pub fn blackbox<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let m = time(3, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(blackbox(i));
            }
            blackbox(s);
        });
        assert!(m.min <= m.median && m.median <= m.max);
        assert!(m.median.as_nanos() > 0);
        assert_eq!(m.runs, 3);
    }

    #[test]
    fn per_iter_divides() {
        let m = Measurement {
            median: Duration::from_micros(1000),
            min: Duration::from_micros(900),
            max: Duration::from_micros(1100),
            runs: 3,
        };
        assert_eq!(m.per_iter(1000), Duration::from_micros(1));
    }
}
