//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] decides, from a seed and a per-mille rate, whether a
//! given *(kind, step, chunk, event#)* coordinate fires a fault. The
//! decision is a pure hash — two runs with the same seed and the same
//! traffic inject faults at the same coordinates, which is what lets
//! the chaos harness (`loadgen --chaos`) make reproducible assertions
//! about error rates and quarantine behavior.
//!
//! Injection sites live on hot paths (every kernel chunk, every arena
//! grow), so the disabled fast path is a single relaxed atomic load:
//! when no plan is installed, `on_chunk`/`on_arena_grow`/`set_step`
//! return immediately without touching the plan slot. This preserves
//! the zero-steady-state-allocation pin (`alloc_steady`) and the
//! bit-exactness pins (`engine_equiv`, `zoo_forward`) — with faults
//! disabled, nothing observable changes.
//!
//! Fault kinds:
//! - **chunk panic** — `panic_any(InjectedFault)` inside a worker
//!   chunk; exercises `WorkerPool` panic isolation and shard
//!   supervision.
//! - **slow chunk** — sleeps `slow_us` inside a chunk; exercises
//!   deadline misses and tail latency under faults.
//! - **arena grow failure** — panics inside `ensure_len`'s grow
//!   branch; exercises arena rebuild on shard recovery.
//! - **torn wire reply** — the server writes half an `OK` line and
//!   drops the connection; exercises client-side retry handling.
//!
//! Install globally with [`install`] (or [`install_from_env`] via
//! `NEUROMAX_CHAOS=seed=1,panic=10,...`), remove with [`clear`].
//! Installation is process-global: tests that install a plan must
//! serialize with each other (see `tests/fault_containment.rs`).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

/// Panic payload used for every injected panic, so supervisors and the
/// process panic hook can tell injected faults from real bugs.
#[derive(Debug)]
pub struct InjectedFault(pub &'static str);

/// Per-kind fault rates (per mille) plus the plan seed. `Default` is
/// all-zero: a plan with no rates never fires.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Seed for the coordinate hash; same seed + same traffic → same
    /// injected faults.
    pub seed: u64,
    /// Chunk-panic rate, per 1000 chunk executions.
    pub panic_per_mille: u32,
    /// Slow-chunk rate, per 1000 chunk executions.
    pub slow_per_mille: u32,
    /// How long a slow chunk sleeps, in microseconds.
    pub slow_us: u64,
    /// Arena-grow failure rate, per 1000 grow events.
    pub grow_per_mille: u32,
    /// Torn-reply rate, per 1000 `OK` replies written.
    pub torn_per_mille: u32,
}

impl FaultSpec {
    /// Parse a `key=value` comma list, e.g.
    /// `seed=1,panic=10,slow=5,slow_us=2000,grow=2,torn=5`.
    /// Unknown keys are an error; omitted keys default to zero.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec `{part}`: expected key=value"))?;
            let n: u64 = val
                .trim()
                .parse()
                .map_err(|_| format!("fault spec `{part}`: bad number `{val}`"))?;
            match key.trim() {
                "seed" => spec.seed = n,
                "panic" => spec.panic_per_mille = n as u32,
                "slow" => spec.slow_per_mille = n as u32,
                "slow_us" => spec.slow_us = n,
                "grow" => spec.grow_per_mille = n as u32,
                "torn" => spec.torn_per_mille = n as u32,
                other => return Err(format!("fault spec: unknown key `{other}`")),
            }
        }
        Ok(spec)
    }
}

/// Installed fault plan: the spec plus live injection counters. The
/// counters let the chaos harness report how many faults actually
/// fired (vs. how many errors surfaced on the wire).
pub struct FaultPlan {
    spec: FaultSpec,
    /// Monotone event counter; decorrelates repeated visits to the
    /// same (step, chunk) coordinate across requests.
    events: AtomicU64,
    /// Current step index, set by the executor before each step so
    /// chunk-level sites know their (step, chunk) coordinate.
    step: AtomicUsize,
    pub panics_injected: AtomicU64,
    pub slows_injected: AtomicU64,
    pub grow_fails_injected: AtomicU64,
    pub torn_injected: AtomicU64,
}

const KIND_PANIC: u64 = 1;
const KIND_SLOW: u64 = 2;
const KIND_GROW: u64 = 3;
const KIND_TORN: u64 = 4;

impl FaultPlan {
    pub fn new(spec: FaultSpec) -> FaultPlan {
        FaultPlan {
            spec,
            events: AtomicU64::new(0),
            step: AtomicUsize::new(0),
            panics_injected: AtomicU64::new(0),
            slows_injected: AtomicU64::new(0),
            grow_fails_injected: AtomicU64::new(0),
            torn_injected: AtomicU64::new(0),
        }
    }

    pub fn spec(&self) -> FaultSpec {
        self.spec
    }

    /// Pure fire decision: hash (seed, kind, step, chunk, event#) and
    /// compare against the per-mille rate. SplitMix64 finalizer — the
    /// same mixer as `util::prng`, applied as a hash.
    fn fires(&self, kind: u64, step: usize, chunk: usize, per_mille: u32) -> bool {
        if per_mille == 0 {
            return false;
        }
        let event = self.events.fetch_add(1, Ordering::Relaxed);
        let mut z = self
            .spec
            .seed
            .wrapping_add(kind.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((step as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add((chunk as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
            .wrapping_add(event.wrapping_mul(0xD6E8_FEB8_6659_FD93));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z % 1000) < per_mille as u64
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn plan_slot() -> &'static Mutex<Option<Arc<FaultPlan>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<FaultPlan>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Install `spec` as the process-global fault plan and return a handle
/// to its counters. Replaces any previously installed plan.
pub fn install(spec: FaultSpec) -> Arc<FaultPlan> {
    let plan = Arc::new(FaultPlan::new(spec));
    *crate::util::sync::plock(plan_slot()) = Some(plan.clone());
    ENABLED.store(true, Ordering::Release);
    plan
}

/// Remove the global fault plan; all injection sites return to the
/// single-atomic-load fast path.
pub fn clear() {
    ENABLED.store(false, Ordering::Release);
    *crate::util::sync::plock(plan_slot()) = None;
}

/// Cheap probe: is any fault plan installed?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Clone the installed plan handle, if any.
pub fn current() -> Option<Arc<FaultPlan>> {
    if !enabled() {
        return None;
    }
    crate::util::sync::plock(plan_slot()).clone()
}

/// Install from the `NEUROMAX_CHAOS` environment variable if set.
/// Returns the plan handle, or `None` when the variable is absent.
/// Panics on a malformed spec (a chaos run with a typo'd spec should
/// fail loudly, not silently run clean).
pub fn install_from_env() -> Option<Arc<FaultPlan>> {
    let raw = std::env::var("NEUROMAX_CHAOS").ok()?;
    let spec = FaultSpec::parse(&raw)
        .unwrap_or_else(|e| panic!("NEUROMAX_CHAOS: {e}"));
    Some(install(spec))
}

/// Record the executing step index; called by the program executor at
/// the top of each step so chunk sites know their coordinate.
#[inline]
pub fn set_step(si: usize) {
    if !enabled() {
        return;
    }
    if let Some(plan) = current() {
        plan.step.store(si, Ordering::Relaxed);
    }
}

/// Chunk-level injection site: may sleep (slow chunk) and may panic
/// (chunk panic). Called at the top of every parallel chunk body and
/// once per serial step.
#[inline]
pub fn on_chunk(chunk: usize) {
    if !enabled() {
        return;
    }
    let Some(plan) = current() else { return };
    let step = plan.step.load(Ordering::Relaxed);
    if plan.fires(KIND_SLOW, step, chunk, plan.spec.slow_per_mille) {
        plan.slows_injected.fetch_add(1, Ordering::Relaxed);
        std::thread::sleep(Duration::from_micros(plan.spec.slow_us));
    }
    if plan.fires(KIND_PANIC, step, chunk, plan.spec.panic_per_mille) {
        plan.panics_injected.fetch_add(1, Ordering::Relaxed);
        std::panic::panic_any(InjectedFault("chunk"));
    }
}

/// Arena-grow injection site: may panic in place of a grow. Called
/// from `ensure_len`'s grow branch only — never on the steady state.
#[inline]
pub fn on_arena_grow() {
    if !enabled() {
        return;
    }
    let Some(plan) = current() else { return };
    let step = plan.step.load(Ordering::Relaxed);
    if plan.fires(KIND_GROW, step, 0, plan.spec.grow_per_mille) {
        plan.grow_fails_injected.fetch_add(1, Ordering::Relaxed);
        std::panic::panic_any(InjectedFault("arena-grow"));
    }
}

/// Wire-level injection site: should this `OK` reply be torn (half
/// written, connection dropped)? The server checks this before
/// writing a success reply.
#[inline]
pub fn torn_reply() -> bool {
    if !enabled() {
        return false;
    }
    let Some(plan) = current() else { return false };
    if plan.fires(KIND_TORN, 0, 0, plan.spec.torn_per_mille) {
        plan.torn_injected.fetch_add(1, Ordering::Relaxed);
        return true;
    }
    false
}

/// Install a process panic hook that suppresses backtrace spew for
/// injected faults and for `PooledJobPanic` (the pool's re-panic
/// wrapper), while delegating real panics to the previous hook.
/// Idempotent; used by chaos runs so thousands of injected panics
/// don't flood stderr.
pub fn silence_injected_panics() {
    use std::sync::Once;
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            if payload.downcast_ref::<InjectedFault>().is_some()
                || payload
                    .downcast_ref::<crate::dataflow::workers::PooledJobPanic>()
                    .is_some()
            {
                return;
            }
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let s = FaultSpec::parse("seed=9,panic=10,slow=5,slow_us=2000,grow=2,torn=5").unwrap();
        assert_eq!(
            s,
            FaultSpec {
                seed: 9,
                panic_per_mille: 10,
                slow_per_mille: 5,
                slow_us: 2000,
                grow_per_mille: 2,
                torn_per_mille: 5,
            }
        );
    }

    #[test]
    fn parse_rejects_unknown_key_and_bad_number() {
        assert!(FaultSpec::parse("bogus=1").is_err());
        assert!(FaultSpec::parse("panic=lots").is_err());
        assert!(FaultSpec::parse("panic").is_err());
    }

    #[test]
    fn parse_empty_spec_is_all_zero() {
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
    }

    #[test]
    fn zero_rate_never_fires() {
        let plan = FaultPlan::new(FaultSpec { seed: 1, ..FaultSpec::default() });
        for i in 0..10_000 {
            assert!(!plan.fires(KIND_PANIC, 0, i, 0));
        }
    }

    #[test]
    fn full_rate_always_fires() {
        let plan = FaultPlan::new(FaultSpec { seed: 1, ..FaultSpec::default() });
        for i in 0..1_000 {
            assert!(plan.fires(KIND_PANIC, i, i, 1000));
        }
    }

    #[test]
    fn fire_rate_tracks_per_mille() {
        let plan = FaultPlan::new(FaultSpec { seed: 42, ..FaultSpec::default() });
        let n = 100_000;
        let hits = (0..n).filter(|&i| plan.fires(KIND_PANIC, 0, i, 10)).count();
        // 10 per mille of 100k = ~1000; allow generous slack.
        assert!(
            (600..1400).contains(&hits),
            "expected ~1000 hits at 10 per mille, got {hits}"
        );
    }

    #[test]
    fn same_seed_same_decisions() {
        let a = FaultPlan::new(FaultSpec { seed: 7, ..FaultSpec::default() });
        let b = FaultPlan::new(FaultSpec { seed: 7, ..FaultSpec::default() });
        for i in 0..5_000 {
            assert_eq!(
                a.fires(KIND_SLOW, i % 13, i, 25),
                b.fires(KIND_SLOW, i % 13, i, 25)
            );
        }
    }
}
