//! Dependency-free utilities: PRNG, mini property-test harness, ASCII
//! tables (offline environment — no rand/proptest/serde crates).

pub mod bench;
pub mod fault;
pub mod prng;
pub mod proptest;
pub mod sync;
pub mod table;
