//! SplitMix64 PRNG — deterministic, dependency-free randomness for tests,
//! property harness and workload generation (no `rand` crate offline).

/// SplitMix64: tiny, fast, well-distributed; each seed gives an independent
/// stream. Not cryptographic (doesn't need to be).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. Uses rejection-free multiply-shift (tiny bias
    /// for astronomically large `n`, irrelevant here).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random sign in {-1, +1}.
    #[inline]
    pub fn sign(&mut self) -> i32 {
        if self.next_u64() & 1 == 0 {
            1
        } else {
            -1
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = SplitMix64::new(1);
        for _ in 0..10_000 {
            let v = r.range_i32(-31, 31);
            assert!((-31..=31).contains(&v));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = SplitMix64::new(3);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }
}
