//! Mini property-testing harness (the `proptest` crate is unavailable in
//! this offline environment, so we roll a seeded-random-cases runner with
//! failure reporting; shrinking is replaced by printing the failing seed so
//! a case can be replayed deterministically).

use super::prng::SplitMix64;

/// Run `cases` random property checks. `f` receives a per-case PRNG and
/// returns `Err(msg)` to fail. Panics with the seed of the first failure.
///
/// The case count can be raised (or lowered) without recompiling via the
/// `PROPTEST_CASES` env var — CI's `graph-tests` job runs the property
/// suites above the default. The override applies only here, not to
/// [`check_seeded`], so a failing-seed replay stays exact.
pub fn check<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut SplitMix64) -> Result<(), String>,
{
    let cases = std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(cases);
    check_seeded(name, 0xC0FFEE, cases, f)
}

/// Append a failing case to the artifact file CI uploads on red
/// (`PROPTEST_FAILURE_FILE`, default `proptest-failures.txt` in the test
/// working directory). Best-effort: reporting must never mask the panic.
fn record_failure(name: &str, case: u64, seed: u64, msg: &str) {
    use std::io::Write;
    let path = std::env::var("PROPTEST_FAILURE_FILE")
        .unwrap_or_else(|_| "proptest-failures.txt".to_string());
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
        let _ = writeln!(f, "{name} case={case} seed={seed:#x}: {msg}");
    }
}

/// Like [`check`] but with an explicit base seed (for replaying failures).
pub fn check_seeded<F>(name: &str, base_seed: u64, cases: u64, f: F)
where
    F: Fn(&mut SplitMix64) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = SplitMix64::new(seed);
        if let Err(msg) = f(&mut rng) {
            record_failure(name, case, seed, &msg);
            panic!(
                "property `{name}` failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with check_seeded(\"{name}\", {seed:#x}, 1, ..)"
            );
        }
    }
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

/// Equality helper with value printing.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr, $($fmt:tt)+) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} (left={:?}, right={:?})",
                format!($($fmt)+), a, b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // count via interior state: use a RefCell-free trick with atomic
        use std::sync::atomic::{AtomicU64, Ordering};
        static N: AtomicU64 = AtomicU64::new(0);
        // check_seeded: exempt from the PROPTEST_CASES override, so the
        // exact-count assertion holds in any environment
        check_seeded("always-true", 0xC0FFEE, 50, |_| {
            N.fetch_add(1, Ordering::Relaxed);
            Ok(())
        });
        count += N.load(Ordering::Relaxed);
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 10, |_| Err("nope".to_string()));
    }

    #[test]
    fn rng_streams_differ_across_cases() {
        use std::sync::Mutex;
        static SEEN: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        check("distinct-streams", 20, |rng| {
            SEEN.lock().unwrap().push(rng.next_u64());
            Ok(())
        });
        let seen = SEEN.lock().unwrap();
        let mut uniq = seen.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seen.len(), "duplicate case streams");
    }
}
