//! Poison-recovering synchronization helpers.
//!
//! `std`'s mutexes poison when a holder panics, and every later
//! `.lock().unwrap()` then panics too — one caught worker panic would
//! otherwise wedge the whole worker pool (and everything queued behind
//! it) forever. The serving stack treats poisoning as survivable: the
//! data guarded by these locks is either scalar bookkeeping (chunk
//! counters, queue depths) or is discarded and rebuilt by the shard
//! supervisor after a fault, so recovering the guard is always sound
//! here. Use these helpers instead of `.lock().unwrap()` on any path
//! that must stay alive across a caught panic.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
#[inline]
pub fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Condvar wait that recovers the guard on poison (see [`plock`]).
#[inline]
pub fn pwait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Bounded condvar wait that recovers the guard on poison (see
/// [`plock`]).
#[inline]
pub fn pwait_timeout<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(g, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Mutex;

    #[test]
    fn plock_recovers_a_poisoned_mutex() {
        let m = Mutex::new(7u32);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(r.is_err());
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        assert_eq!(*plock(&m), 7, "plock must still hand out the guard");
        *plock(&m) = 8;
        assert_eq!(*plock(&m), 8);
    }
}
