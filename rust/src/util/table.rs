//! ASCII table printer for paper-table reports (`neuromax report ...`).

/// Render rows as a boxed ASCII table. First row is the header.
pub fn render(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap_or(0);
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let sep = {
        let mut s = String::from("+");
        for w in &widths {
            s.push_str(&"-".repeat(w + 2));
            s.push('+');
        }
        s
    };
    let mut out = String::new();
    out.push_str(&sep);
    out.push('\n');
    for (ri, row) in rows.iter().enumerate() {
        out.push('|');
        for (i, w) in widths.iter().enumerate() {
            let cell = row.get(i).map(String::as_str).unwrap_or("");
            out.push_str(&format!(" {cell:<w$} |"));
        }
        out.push('\n');
        if ri == 0 {
            out.push_str(&sep);
            out.push('\n');
        }
    }
    out.push_str(&sep);
    out.push('\n');
    out
}

/// Convenience: build a row from display-ables.
#[macro_export]
macro_rules! row {
    ($($cell:expr),+ $(,)?) => {
        vec![$(format!("{}", $cell)),+]
    };
}

/// Format a float with fixed decimals, trimming noise.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format a large count with thousands separators (e.g. 12_345_678).
pub fn count(v: u64) -> String {
    let s = v.to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let t = render(&[
            vec!["Layer".into(), "Cycles".into()],
            vec!["conv1".into(), "123".into()],
        ]);
        assert!(t.contains("| Layer | Cycles |"));
        assert!(t.contains("| conv1 | 123    |"));
        // three separators: top, under-header, bottom
        assert_eq!(t.matches('+').count() / 3, 3);
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1,000");
        assert_eq!(count(12345678), "12,345,678");
    }

    #[test]
    fn empty_table_is_empty() {
        assert_eq!(render(&[]), "");
    }
}
