//! Zero-allocation steady state: after warmup, the compiled-program
//! serve loop must not touch the heap at all.
//!
//! A counting global allocator wraps `System`; we warm a
//! `ProgramExecutor` (arena slots grow to their program-wide maxima,
//! the column scratch and the caller's output buffer acquire capacity),
//! then assert that further requests perform **zero** allocations.
//! This is the enforcement half of the arena design — `allocs_per_req`
//! in the serving metrics reports the same property as a gauge.
//!
//! This file intentionally holds a single test: the allocator counter
//! is process-global, and a concurrently-running sibling test would
//! pollute the measured window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use neuromax::dataflow::program::{ModelProgram, ProgramExecutor};
use neuromax::dataflow::Engine;
use neuromax::models::runner::{random_input_for, NetWeights};
use neuromax::models::workload;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn warmed_program_executor_serves_without_heap_allocations() {
    // serial engine: the measurement must not include worker-thread
    // machinery (the pool parks between jobs without allocating, but
    // thread wakeup paths are platform-dependent — the allocation
    // property being pinned here is the executor's)
    let eng = Engine::single_threaded();
    // chain, concat-branchy, and residual-branchy representatives
    for name in ["tinycnn", "squeezenet", "resnet34"] {
        let net = workload::test_profile(name).unwrap();
        let w = NetWeights::random(&net, 7);
        let fused = w.fuse();
        let prog = Arc::new(ModelProgram::compile(&net).unwrap());
        let mut ex = ProgramExecutor::new(prog);
        let x = random_input_for(&net, 1);
        let mut out = Vec::new();

        // warmup: arena slots, column scratch and the output buffer all
        // reach their high-water capacity
        for _ in 0..3 {
            ex.run_into(&eng, &fused, &x, &mut out);
        }
        let expected = out.clone();
        let warm_grows = ex.arena_grow_events();
        // the pin must cover the packed-GEMM path: at this engine shape
        // every zoo profile routes at least one conv through it, so a
        // GEMM-side allocation (panel pack, scratch growth) after warmup
        // would fail the zero-allocation assert below
        let plan = ex.program().plans_for(1, false, false);
        assert!(
            plan.steps.iter().any(|p| p.gemm.is_some()),
            "{name}: no step routed to the GEMM kernel — pin no longer covers it"
        );

        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..10 {
            ex.run_into(&eng, &fused, &x, &mut out);
        }
        let after = ALLOCS.load(Ordering::Relaxed);

        assert_eq!(out, expected, "{name}: steady-state output drifted");
        assert_eq!(
            ex.arena_grow_events(),
            warm_grows,
            "{name}: arena grew after warmup"
        );
        assert_eq!(
            after - before,
            0,
            "{name}: steady-state serve loop allocated {} times",
            after - before
        );
    }
}
