//! Shared graph generators + the slot-provenance replay checker, used by
//! `program_slots.rs` (flat zoo-like layer lists) and `ir_passes.rs`
//! (typed-IR graphs, including shapes the flat language cannot express:
//! diamond fan-out, back-to-back concats, orphan branches, staged
//! merges).
#![allow(dead_code)]

use neuromax::dataflow::ir::{Graph, GraphBuilder, NodeId};
use neuromax::dataflow::program::{Input, Kernel, Merge, ModelProgram, Operand};
use neuromax::models::layer::{LayerDesc, Network};
use neuromax::util::prng::SplitMix64;

/// Generate a random routable zoo-like network. Shape-preserving ops
/// keep the bookkeeping exact; fire and residual segments leave their
/// merge pending for the *next* layer (exactly how the plan inference
/// discovers them), so the generator always materializes a join before
/// ending or branching again.
///
/// Beyond the zoo shapes, the generator sometimes emits an **orphan**
/// layer (a pointwise nothing ever consumes — its ≥33-channel output
/// can never shape-match a later layer, so routing is unaffected; the
/// IR pipeline's dead-node elimination drops it) and a **post-fc
/// pointwise tail** (a 1×1-map pointwise the 1×1-conv→fc pass retags).
pub fn random_net(rng: &mut SplitMix64, tag: u64) -> Network {
    let mut h = 6 + rng.below(7) as usize;
    let mut w = 6 + rng.below(5) as usize;
    let mut c = 1 + rng.below(3) as usize;
    let mut layers: Vec<LayerDesc> = Vec::new();
    let mut li = 0usize;
    let name = |li: &mut usize, s: &str| {
        *li += 1;
        format!("{s}{li}")
    };
    // a plain shape-compatible consumer: conv3/conv1/depthwise/pool
    let plain = |rng: &mut SplitMix64,
                 layers: &mut Vec<LayerDesc>,
                 li: &mut usize,
                 h: &mut usize,
                 w: &mut usize,
                 c: &mut usize| {
        match rng.below(4) {
            0 => {
                let co = 1 + rng.below(5) as usize;
                layers.push(LayerDesc::conv(
                    &format!("c3_{li}"), 3, 1, 1, *h, *w, *c, co,
                ));
                *li += 1;
                *c = co;
            }
            1 => {
                let co = 1 + rng.below(5) as usize;
                layers.push(LayerDesc::pointwise(&format!("pw{li}"), *h, *w, *c, co));
                *li += 1;
                *c = co;
            }
            2 => {
                layers.push(LayerDesc::depthwise(&format!("dw{li}"), 1, *h, *w, *c));
                *li += 1;
            }
            _ => {
                if *h >= 4 && *w >= 4 {
                    if rng.bool(0.5) {
                        layers.push(LayerDesc::pool(&format!("mp{li}"), 2, 2, *h, *w, *c));
                    } else {
                        layers.push(LayerDesc::avgpool(&format!("ap{li}"), 2, 2, *h, *w, *c));
                    }
                    *li += 1;
                    *h = (*h - 2) / 2 + 1;
                    *w = (*w - 2) / 2 + 1;
                } else {
                    layers.push(LayerDesc::depthwise(&format!("dw{li}"), 1, *h, *w, *c));
                    *li += 1;
                }
            }
        }
    };
    let segments = 2 + rng.below(3);
    for _ in 0..segments {
        match rng.below(4) {
            // fire module: squeeze → two expand branches → (pending concat)
            0 => {
                let s = 1 + rng.below(3) as usize;
                let c1 = 1 + rng.below(3) as usize;
                let c2 = 1 + rng.below(3) as usize;
                layers.push(LayerDesc::pointwise(&name(&mut li, "sq"), h, w, c, s));
                layers.push(LayerDesc::pointwise(&name(&mut li, "e1_"), h, w, s, c1));
                layers.push(LayerDesc::conv(&name(&mut li, "e3_"), 3, 1, 1, h, w, s, c2));
                c = c1 + c2;
                // materialize the concat in a plain consumer
                plain(rng, &mut layers, &mut li, &mut h, &mut w, &mut c);
            }
            // residual pair: A (3×3, channel change) beside B (1×1
            // projection re-reading A's input) → (pending merge)
            1 => {
                let co = c + 1 + rng.below(3) as usize; // co != c: B re-reads
                layers.push(LayerDesc::conv(&name(&mut li, "ra"), 3, 1, 1, h, w, c, co));
                layers.push(LayerDesc::pointwise(&name(&mut li, "rb"), h, w, c, co));
                c = co;
                // materialize the merge in a plain consumer
                plain(rng, &mut layers, &mut li, &mut h, &mut w, &mut c);
            }
            _ => plain(rng, &mut layers, &mut li, &mut h, &mut w, &mut c),
        }
        // orphan: consumed by nothing (channel count ≥33 can never
        // match a later layer, every generator channel stays far below)
        if rng.bool(0.25) {
            let dead = 33 + rng.below(8) as usize;
            layers.push(LayerDesc::pointwise(&name(&mut li, "dead"), h, w, c, dead));
        }
    }
    if rng.bool(0.6) {
        let fco = 1 + rng.below(8) as usize;
        layers.push(LayerDesc::fc("fc", h * w * c, fco));
        // pointwise head on the 1×1 map: the 1×1-conv→fc rewrite target
        if rng.bool(0.3) {
            layers.push(LayerDesc::pointwise("pwhead", 1, 1, fco, 1 + rng.below(6) as usize));
        }
    }
    Network { name: format!("randgraph-{tag}"), layers }
}

/// Replay a compiled program's slot traffic, asserting every read sees
/// the producer it was compiled against and no step aliases its own
/// reads. Works for both compile paths: flat-plan programs and IR
/// programs (n-ary concats, [`Kernel::Stage`] steps whose stage slot
/// *is* the output slot by design).
pub fn check_slot_provenance(prog: &ModelProgram) -> Result<(), String> {
    let mut owner: Vec<Option<usize>> = vec![None; prog.slot_sizes.len()];
    let read_ok = |owner: &[Option<usize>], op: &Operand, step: usize| -> Result<(), String> {
        if let Some(s) = op.slot {
            if owner[s] != Some(op.src_layer) {
                return Err(format!(
                    "step {step} reads slot {s} expecting layer {}, but it holds {:?} \
                     (recycled before last use)",
                    op.src_layer, owner[s]
                ));
            }
        }
        Ok(())
    };
    for (i, step) in prog.steps.iter().enumerate() {
        let mut reads: Vec<usize> = Vec::new();
        let mut see = |op: &Operand| {
            if let Some(s) = op.slot {
                reads.push(s);
            }
        };
        match &step.input {
            Input::Direct(op) => {
                read_ok(&owner, op, i)?;
                see(op);
            }
            Input::Staged(sp) => {
                match &sp.merge {
                    Merge::Copy(a) => {
                        read_ok(&owner, a, i)?;
                        see(a);
                    }
                    Merge::Concat(parts) => {
                        for p in parts {
                            read_ok(&owner, p, i)?;
                            see(p);
                        }
                    }
                    Merge::Residual(a, b) => {
                        read_ok(&owner, a, i)?;
                        read_ok(&owner, b, i)?;
                        see(a);
                        see(b);
                    }
                }
                if reads.contains(&sp.slot) {
                    return Err(format!("step {i}: stage slot {} aliases a read", sp.slot));
                }
                // Stage steps materialize the merge: the stage slot IS
                // the output slot; everywhere else staging is transient
                if sp.slot == step.out_slot && step.kernel != Kernel::Stage {
                    return Err(format!("step {i}: stage slot == out slot {}", sp.slot));
                }
                owner[sp.slot] = None;
            }
        }
        if reads.contains(&step.out_slot) {
            return Err(format!("step {i}: out slot {} aliases a read", step.out_slot));
        }
        owner[step.out_slot] = Some(step.layer);
    }
    Ok(())
}

/// Deterministic diamond graph: one producer fanned out to two compute
/// branches rejoined by a residual — a structure the flat layer-list
/// language cannot express (its plan inference reads the same four
/// descriptors as a straight chain).
pub fn diamond_graph() -> Graph {
    let mut b = GraphBuilder::new("diamond", 8, 8, 3);
    let a = b.conv(b.input(), 3, 1, 1, 4).unwrap();
    let p = b.conv(a, 3, 1, 1, 4).unwrap();
    let q = b.pointwise(a, 4).unwrap();
    let r = b.residual(p, q).unwrap();
    let out = b.conv(r, 3, 1, 1, 5).unwrap();
    b.finish(out).unwrap()
}

/// Deterministic graph whose concat value is read by **two** kernel
/// consumers — unfoldable into either, so the program compiler must
/// materialize it with a [`Kernel::Stage`] step.
pub fn stage_graph() -> Graph {
    let mut b = GraphBuilder::new("staged", 6, 6, 2);
    let a = b.conv(b.input(), 3, 1, 1, 3).unwrap();
    let p = b.pointwise(a, 2).unwrap();
    let q = b.depthwise(a, 1).unwrap();
    let j = b.concat(&[p, q]).unwrap(); // 2 + 3 = 5 channels
    let u = b.pointwise(j, 4).unwrap();
    let v = b.conv(j, 3, 1, 1, 4).unwrap();
    let r = b.residual(u, v).unwrap();
    let out = b.pointwise(r, 3).unwrap();
    b.finish(out).unwrap()
}

/// Generate a random typed-IR graph via the builder: spatial-preserving
/// kernels plus the shapes only the IR expresses — diamond fan-out
/// (residual rejoin of a shared producer), concat joins (sometimes
/// nested, exercising chain elision), and orphan branches (dead-node
/// elimination fodder) — optionally capped by an fc head.
pub fn random_graph(rng: &mut SplitMix64, tag: u64) -> Graph {
    let h = 6 + rng.below(5) as usize;
    let w = 6 + rng.below(5) as usize;
    let c = 1 + rng.below(3) as usize;
    let mut b = GraphBuilder::new(&format!("randir-{tag}"), h, w, c);
    let mut cur = b.input();
    fn step(b: &mut GraphBuilder, rng: &mut SplitMix64, src: NodeId) -> NodeId {
        match rng.below(3) {
            0 => b.conv(src, 3, 1, 1, 1 + rng.below(4) as usize).unwrap(),
            1 => b.pointwise(src, 1 + rng.below(4) as usize).unwrap(),
            _ => b.depthwise(src, 1).unwrap(),
        }
    }
    for _ in 0..(2 + rng.below(3)) {
        match rng.below(4) {
            // diamond: fan out, rejoin by residual (same cout each side)
            0 => {
                let co = 1 + rng.below(4) as usize;
                let p = b.conv(cur, 3, 1, 1, co).unwrap();
                let q = b.pointwise(cur, co).unwrap();
                cur = b.residual(p, q).unwrap();
            }
            // concat join, sometimes nested (back-to-back concats)
            1 => {
                let p = step(&mut b, rng, cur);
                let q = step(&mut b, rng, cur);
                let j = if rng.bool(0.5) {
                    let r = step(&mut b, rng, cur);
                    let inner = b.concat(&[p, q]).unwrap();
                    b.concat(&[inner, r]).unwrap()
                } else {
                    b.concat(&[p, q]).unwrap()
                };
                cur = b.pointwise(j, 1 + rng.below(4) as usize).unwrap();
            }
            // orphan branch: built, never reaches the output
            2 => {
                let _dead = b.pointwise(cur, 5 + rng.below(4) as usize).unwrap();
                cur = step(&mut b, rng, cur);
            }
            _ => cur = step(&mut b, rng, cur),
        }
    }
    if rng.bool(0.4) {
        cur = b.fc(cur, 1 + rng.below(6) as usize).unwrap();
    }
    b.finish(cur).unwrap()
}
