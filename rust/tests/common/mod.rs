//! Shared helpers for integration tests: locating `artifacts/`, parsing
//! the python-generated test-vector files (`tv_*.txt`), and the graph
//! generators shared by the program/IR property suites ([`graphgen`]).

pub mod graphgen;

use std::path::PathBuf;

use neuromax::tensor::{Tensor3, Tensor4};

/// The artifacts directory, or `None` if `make artifacts` hasn't run or
/// the PJRT runtime isn't compiled in (tests that need the executables
/// skip gracefully with a loud note).
pub fn artifacts_dir() -> Option<PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: pjrt feature off (stub runtime cannot execute artifacts)");
        return None;
    }
    let dir = std::env::var_os("NEUROMAX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!(
            "SKIP: artifacts not built (run `make artifacts`); looked in {}",
            dir.display()
        );
        None
    }
}

#[allow(dead_code)]
pub fn read(dir: &std::path::Path, name: &str) -> String {
    std::fs::read_to_string(dir.join(name))
        .unwrap_or_else(|e| panic!("reading {name}: {e}"))
}

/// Parse a `key v1 v2 ...` line map from a tv file.
#[allow(dead_code)]
pub fn kv_lines(text: &str) -> std::collections::HashMap<String, Vec<i64>> {
    let mut map = std::collections::HashMap::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        if let Some(key) = it.next() {
            let vals: Vec<i64> = it.map(|v| v.parse().expect("int")).collect();
            map.insert(key.to_string(), vals);
        }
    }
    map
}

/// A conv test case parsed from `tv_conv*.txt`.
#[allow(dead_code)]
pub struct ConvCase {
    pub a: Tensor3,
    pub wc: Tensor4,
    pub ws: Tensor4,
    pub stride: usize,
    pub out: Vec<i32>,
    pub req: Option<Vec<i32>>,
}

#[allow(dead_code)]
pub fn conv_case(dir: &std::path::Path, name: &str) -> ConvCase {
    let text = read(dir, name);
    let kv = kv_lines(&text);
    let sa = &kv["shape_a"];
    let sw = &kv["shape_w"];
    let stride = kv.get("stride").map(|v| v[0] as usize).unwrap_or(1);
    let to_i32 = |v: &Vec<i64>| v.iter().map(|&x| x as i32).collect::<Vec<_>>();
    ConvCase {
        a: Tensor3::from_vec(sa[0] as usize, sa[1] as usize, sa[2] as usize, to_i32(&kv["a"])),
        wc: Tensor4::from_vec(
            sw[0] as usize, sw[1] as usize, sw[2] as usize, sw[3] as usize,
            to_i32(&kv["wc"]),
        ),
        ws: Tensor4::from_vec(
            sw[0] as usize, sw[1] as usize, sw[2] as usize, sw[3] as usize,
            to_i32(&kv["ws"]),
        ),
        stride,
        out: to_i32(&kv["out"]),
        req: kv.get("req").map(to_i32),
    }
}
