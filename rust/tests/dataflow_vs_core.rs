//! The three implementations of the 3×3 dataflow must agree:
//!  * `arch::conv_core` (hardware-faithful: adder nets, shift registers),
//!  * `dataflow::exec` (fast functional),
//!  * `dataflow::schedule` (analytic cycle model — no numerics).
//! Bit-equality for psums; cycle-equality between the faithful core and
//! the analytic model (they implement the same Fig. 8 schedule).

mod common;

use neuromax::arch::config::GridConfig;
use neuromax::arch::ConvCore;
use neuromax::dataflow::{analyze, exec, ScheduleOptions};
use neuromax::lns::logquant::ZERO_CODE;
use neuromax::models::layer::LayerDesc;
use neuromax::tensor::{Tensor3, Tensor4};
use neuromax::util::prng::SplitMix64;

fn rand_case(
    rng: &mut SplitMix64, h: usize, w: usize, c: usize, k: usize,
) -> (Tensor3, Tensor4, Tensor4) {
    let mut a = Tensor3::new(h, w, c);
    for v in a.data.iter_mut() {
        *v = if rng.bool(0.1) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    let mut wc = Tensor4::new(k, 3, 3, c);
    let mut ws = Tensor4::new(k, 3, 3, c);
    for v in wc.data.iter_mut() {
        *v = if rng.bool(0.1) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }
    (a, wc, ws)
}

#[test]
fn psums_and_cycles_agree_across_implementations() {
    let grid = GridConfig::neuromax();
    neuromax::util::proptest::check("core-vs-exec-vs-analytic", 20, |rng| {
        let stride = if rng.bool(0.5) { 1 } else { 2 };
        let h = 3 + stride + rng.below(20) as usize;
        let w = 3 + stride + rng.below(14) as usize;
        let c = 1 + rng.below(9) as usize;
        let k = 1 + rng.below(3) as usize;
        let (a, wc, ws) = rand_case(rng, h, w, c, k);

        let fast = exec::conv2d(&a, &wc, &ws, stride);
        let mut core = ConvCore::default();
        let (faithful, stats) = core.conv3x3(&a, &wc, &ws, stride);
        neuromax::prop_assert!(
            fast == faithful,
            "psums differ at h={h} w={w} c={c} k={k} s={stride}"
        );

        // analytic model (no padding → hin=h) must predict the same cycles
        let l = LayerDesc::conv("t", 3, stride, 0, h, w, c, k);
        let perf = analyze(&grid, &l, ScheduleOptions::default());
        neuromax::prop_assert!(
            perf.cycles == stats.cycles,
            "cycle mismatch: analytic {} vs faithful {} (h={h} w={w} c={c} k={k} s={stride})",
            perf.cycles,
            stats.cycles
        );
        neuromax::prop_assert!(
            perf.macs == stats.useful_macs,
            "mac mismatch: {} vs {}",
            perf.macs,
            stats.useful_macs
        );
        Ok(())
    });
}

#[test]
fn psum_storage_counters_agree() {
    let mut rng = SplitMix64::new(11);
    let (a, wc, ws) = rand_case(&mut rng, 18, 10, 2, 2);
    let mut core = ConvCore::default();
    let (_, stats) = core.conv3x3(&a, &wc, &ws, 1);
    let l = LayerDesc::conv("t", 3, 1, 0, 18, 10, 2, 2);
    let perf = analyze(&GridConfig::neuromax(), &l, ScheduleOptions::default());
    assert_eq!(perf.psums_stored, stats.psums_stored);
}

#[test]
fn padded_layer_equals_padded_direct_conv() {
    let mut rng = SplitMix64::new(13);
    let (a, wc, ws) = rand_case(&mut rng, 9, 9, 3, 2);
    let grid = GridConfig::neuromax();
    let l = LayerDesc::conv("p", 3, 1, 1, 9, 9, 3, 2);
    let (out, _) = exec::run_layer(
        &grid, &l, &a, Some(&wc), Some(&ws), ScheduleOptions::default());
    // SAME conv: output dims match input
    assert_eq!((out.h, out.w, out.c), (9, 9, 2));
    // interior equals the unpadded valid conv shifted by 1
    let valid = exec::conv2d(&a, &wc, &ws, 1);
    for i in 0..valid.h {
        for j in 0..valid.w {
            for ch in 0..valid.c {
                assert_eq!(out.get(i + 1, j + 1, ch), valid.get(i, j, ch));
            }
        }
    }
}

#[test]
fn maxpool_commutes_with_requant() {
    // requant is monotone, so maxpool-then-requant == requant-then-maxpool
    let mut rng = SplitMix64::new(17);
    let mut psums = Tensor3::new(8, 8, 3);
    for v in psums.data.iter_mut() {
        *v = rng.range_i32(-1_000_000, 1_000_000);
    }
    let a = exec::requant(&psums);
    let path1 = neuromax::dataflow::pool::maxpool(&a, 2, 2);
    let pooled_psums = neuromax::dataflow::pool::maxpool(&psums, 2, 2);
    let path2 = exec::requant(&pooled_psums);
    assert_eq!(path1, path2);
}
