//! Engine bit-exactness properties: the LUT-fused multi-threaded engine
//! (`dataflow::engine`) must agree bit-for-bit with the reference
//! executor (`dataflow::exec`) and, for 3×3 layers, with the
//! hardware-faithful `arch::ConvCore` — across random shapes, strides,
//! padding, zero-code density, and worker-thread counts.

use neuromax::arch::config::GridConfig;
use neuromax::arch::ConvCore;
use neuromax::dataflow::{exec, Engine, FusedWeights, ScheduleOptions};
use neuromax::lns::logquant::ZERO_CODE;
use neuromax::models::layer::LayerDesc;
use neuromax::models::tinycnn::TinyCnnWeights;
use neuromax::runtime::verify;
use neuromax::tensor::{Tensor3, Tensor4};
use neuromax::util::prng::SplitMix64;
use neuromax::util::proptest::check;

const THREADS: [usize; 2] = [1, 4];

fn rand_t3(rng: &mut SplitMix64, h: usize, w: usize, c: usize, pz: f64) -> Tensor3 {
    let mut t = Tensor3::new(h, w, c);
    for v in t.data.iter_mut() {
        *v = if rng.bool(pz) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    t
}

fn rand_t4(
    rng: &mut SplitMix64,
    k: usize,
    kh: usize,
    kw: usize,
    c: usize,
    pz: f64,
) -> (Tensor4, Tensor4) {
    let mut wc = Tensor4::new(k, kh, kw, c);
    let mut ws = Tensor4::new(k, kh, kw, c);
    for v in wc.data.iter_mut() {
        *v = if rng.bool(pz) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }
    (wc, ws)
}

#[test]
fn conv_3x3_engine_equals_exec_and_core() {
    check("engine-3x3-vs-exec-vs-core", 20, |rng| {
        let stride = if rng.bool(0.5) { 1 } else { 2 };
        let h = 3 + stride + rng.below(20) as usize;
        let w = 3 + stride + rng.below(14) as usize;
        let c = 1 + rng.below(9) as usize;
        let k = 1 + rng.below(4) as usize;
        let pz = if rng.bool(0.3) { 0.6 } else { 0.1 }; // mix in ZERO-dense cases
        let a = rand_t3(rng, h, w, c, pz);
        let (wc, ws) = rand_t4(rng, k, 3, 3, c, pz);

        let want = exec::conv2d(&a, &wc, &ws, stride);
        let fused = FusedWeights::fuse(&wc, &ws);
        for threads in THREADS {
            let got = Engine::with_threads_forced(threads).conv2d(&a, &fused, stride);
            neuromax::prop_assert!(
                got == want,
                "engine != exec at h={h} w={w} c={c} k={k} s={stride} pz={pz} t={threads}"
            );
        }
        let mut core = ConvCore::default();
        let (faithful, _) = core.conv3x3(&a, &wc, &ws, stride);
        neuromax::prop_assert!(
            want == faithful,
            "exec != faithful core at h={h} w={w} c={c} k={k} s={stride}"
        );
        Ok(())
    });
}

#[test]
fn conv_generic_kernels_engine_equals_exec() {
    check("engine-kxk-vs-exec", 20, |rng| {
        let kk = [1usize, 2, 4, 5, 7][rng.below(5) as usize];
        let stride = 1 + rng.below(2) as usize;
        let h = kk + stride + rng.below(16) as usize;
        let w = kk + stride + rng.below(12) as usize;
        let c = 1 + rng.below(8) as usize;
        let k = 1 + rng.below(5) as usize;
        let a = rand_t3(rng, h, w, c, 0.15);
        let (wc, ws) = rand_t4(rng, k, kk, kk, c, 0.15);

        let want = exec::conv2d(&a, &wc, &ws, stride);
        let fused = FusedWeights::fuse(&wc, &ws);
        for threads in THREADS {
            let got = Engine::with_threads_forced(threads).conv2d(&a, &fused, stride);
            neuromax::prop_assert!(
                got == want,
                "engine != exec at kk={kk} h={h} w={w} c={c} k={k} s={stride} t={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn depthwise_engine_equals_exec() {
    check("engine-dw-vs-exec", 15, |rng| {
        let stride = 1 + rng.below(2) as usize;
        let h = 4 + rng.below(16) as usize;
        let w = 4 + rng.below(12) as usize;
        let c = 1 + rng.below(10) as usize;
        let a = rand_t3(rng, h, w, c, 0.2);
        let (wc, ws) = rand_t4(rng, c, 3, 3, 1, 0.2);

        let want = exec::depthwise(&a, &wc, &ws, stride);
        let fused = FusedWeights::fuse(&wc, &ws);
        for threads in THREADS {
            let got = Engine::with_threads_forced(threads).depthwise(&a, &fused, stride);
            neuromax::prop_assert!(
                got == want,
                "depthwise engine != exec at h={h} w={w} c={c} s={stride} t={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn fc_and_pointwise_engine_equal_exec() {
    check("engine-fc-pw-vs-exec", 15, |rng| {
        let h = 2 + rng.below(6) as usize;
        let w = 2 + rng.below(6) as usize;
        let c = 1 + rng.below(12) as usize;
        let k = 1 + rng.below(12) as usize;
        let a = rand_t3(rng, h, w, c, 0.15);

        let (pc, ps) = rand_t4(rng, k, 1, 1, c, 0.15);
        let want = exec::pointwise(&a, &pc, &ps, 1);
        let fpw = FusedWeights::fuse(&pc, &ps);
        for threads in THREADS {
            let got = Engine::with_threads_forced(threads).pointwise(&a, &fpw, 1);
            neuromax::prop_assert!(
                got == want,
                "pointwise engine != exec at h={h} w={w} c={c} k={k} t={threads}"
            );
        }

        let n = a.len();
        let (fc_c, fc_s) = rand_t4(rng, k, 1, 1, n, 0.15);
        let want = exec::fc(&a, &fc_c, &fc_s);
        let ffc = FusedWeights::fuse(&fc_c, &fc_s);
        for threads in THREADS {
            let got = Engine::with_threads_forced(threads).fc(&a, &ffc);
            neuromax::prop_assert!(
                got == want,
                "fc engine != exec at n={n} k={k} t={threads}"
            );
        }
        Ok(())
    });
}

#[test]
fn run_layer_with_padding_equals_exec_run_layer() {
    let grid = GridConfig::neuromax();
    check("engine-runlayer-vs-exec", 12, |rng| {
        let pad = rng.below(3) as usize;
        let stride = 1 + rng.below(2) as usize;
        let hw = 5 + rng.below(12) as usize;
        let c = 1 + rng.below(6) as usize;
        let k = 1 + rng.below(6) as usize;
        let l = LayerDesc::conv("t", 3, stride, pad, hw, hw, c, k);
        let a = rand_t3(rng, hw, hw, c, 0.15);
        let (wc, ws) = rand_t4(rng, k, 3, 3, c, 0.15);

        let (want, perf_want) = exec::run_layer(
            &grid, &l, &a, Some(&wc), Some(&ws), ScheduleOptions::default());
        let fused = FusedWeights::fuse(&wc, &ws);
        for threads in THREADS {
            let (got, perf_got) = Engine::with_threads_forced(threads).run_layer(
                &grid, &l, &a, Some(&fused), ScheduleOptions::default());
            neuromax::prop_assert!(
                got == want,
                "run_layer mismatch at hw={hw} pad={pad} s={stride} c={c} k={k} t={threads}"
            );
            neuromax::prop_assert!(
                perf_got.cycles == perf_want.cycles,
                "perf cycles diverged: {} vs {}",
                perf_got.cycles,
                perf_want.cycles
            );
        }
        Ok(())
    });
}

#[test]
fn tinycnn_serving_forward_is_bit_identical() {
    // the end-to-end chain the serving path runs: reference vs engine at
    // both thread counts, plus the batched entry point
    for seed in 0..3u64 {
        let w = TinyCnnWeights::random(seed ^ 0xABCD);
        let fused = w.fuse();
        let inputs: Vec<Tensor3> = (0..5)
            .map(|i| neuromax::models::tinycnn::random_input(seed * 100 + i))
            .collect();
        let reference: Vec<Vec<i32>> = inputs
            .iter()
            .map(|a| verify::tinycnn_forward_sim(a, &w))
            .collect();
        for threads in THREADS {
            let eng = Engine::with_threads_forced(threads);
            for (a, want) in inputs.iter().zip(&reference) {
                assert_eq!(
                    &verify::tinycnn_forward_engine(&eng, &fused, a),
                    want,
                    "seed={seed} threads={threads}"
                );
            }
            let batch = verify::tinycnn_forward_batch(&eng, &fused, &inputs);
            assert_eq!(batch, reference, "batch seed={seed} threads={threads}");
        }
    }
}
