//! Golden-file pins for `EXPLAIN` output on the six zoo test profiles.
//!
//! `EXPLAIN` rows derive from compiled IR steps (post pass-pipeline), so
//! this is the regression net over the whole plan surface: lowering,
//! rewrites, slot assignment, kernel selection, split/chunk planning and
//! the utilization columns. Any intentional change to one of those
//! reads as a golden diff — regenerate with `NEUROMAX_UPDATE_GOLDEN=1`
//! and review the diff like code (see `tests/golden/README.md`).
//!
//! Plans are compiled for a fixed 4-thread pooled engine; everything in
//! a row is a deterministic function of the program, so the files are
//! stable across machines.

use std::path::PathBuf;

use neuromax::dataflow::program::{cached_program, explain_rows};
use neuromax::models::workload;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

#[test]
fn explain_output_matches_the_goldens() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).expect("create golden dir");
    let update = std::env::var_os("NEUROMAX_UPDATE_GOLDEN").is_some();
    let mut bootstrapped = Vec::new();
    for name in workload::ZOO_NAMES {
        let net = workload::test_profile(name).unwrap();
        let prog = cached_program(&net).unwrap_or_else(|e| panic!("{name}: {e}"));
        let plan = prog.plans_for(4, true, false);
        let text = explain_rows(&net, &prog, &plan).join("\n") + "\n";
        let path = dir.join(format!("{name}.txt"));
        if update || !path.exists() {
            std::fs::write(&path, &text).unwrap_or_else(|e| panic!("{name}: write: {e}"));
            bootstrapped.push(name);
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        if text != want {
            let diff: Vec<String> = text
                .lines()
                .zip(want.lines())
                .enumerate()
                .filter(|(_, (a, b))| a != b)
                .map(|(i, (a, b))| format!("  line {}:\n    got:  {a}\n    want: {b}", i + 1))
                .take(5)
                .collect();
            panic!(
                "{name}: EXPLAIN drifted from tests/golden/{name}.txt \
                 ({} vs {} lines){}{}\nIf intentional, regenerate with \
                 NEUROMAX_UPDATE_GOLDEN=1 and review the diff.",
                text.lines().count(),
                want.lines().count(),
                if diff.is_empty() { "" } else { ":\n" },
                diff.join("\n"),
            );
        }
    }
    if !bootstrapped.is_empty() {
        eprintln!(
            "NOTE: bootstrapped golden files for {bootstrapped:?} — \
             commit tests/golden/*.txt to pin them"
        );
    }
}
