//! Fault-containment integration tests: injected panics answer
//! `ERR internal` instead of killing threads, shards quarantine after
//! consecutive failures and recover after a rebuild, deadline-refused
//! requests never execute, and stalled connections are reaped while
//! live ones keep serving.
//!
//! Fault installation (`util::fault::install`) is **process-global**,
//! so every test in this binary serializes through [`faults_guard`] —
//! and tests that install plans live ONLY in this file. Each test
//! clears any leftover plan on entry so a panicked predecessor cannot
//! poison it.

use std::io::Read;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Mutex, MutexGuard, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use neuromax::coordinator::batcher::BatchPolicy;
use neuromax::coordinator::health::{HealthPolicy, HealthState};
use neuromax::coordinator::metrics::ErrCode;
use neuromax::coordinator::pipeline::Backend;
use neuromax::coordinator::server::{Client, ConnPolicy, Reply, Server};
use neuromax::coordinator::shard::{Admission, JobKind, Pending, ShardPool, ShardReply};
use neuromax::dataflow::engine::EngineOptions;
use neuromax::util::fault::{self, FaultSpec};

/// Serialize tests that touch the process-global fault plan. Poison is
/// recovered on purpose: a failing test must not wedge the rest.
fn faults_guard() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn workers(n: usize) -> EngineOptions {
    EngineOptions { num_threads: n, ..Default::default() }
}

fn tight_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1), ..Default::default() }
}

/// Submit one default-model request and wait for its reply.
fn roundtrip(pool: &ShardPool, seed: u64) -> Result<ShardReply, Admission> {
    let (tx, rx) = mpsc::channel();
    pool.submit(Pending {
        kind: JobKind::Infer,
        model: None,
        seed,
        enqueued: Instant::now(),
        deadline: None,
        reply: tx,
    })?;
    Ok(rx.recv_timeout(Duration::from_secs(10)).expect("shard must answer"))
}

#[test]
fn injected_panic_answers_err_internal_and_the_server_keeps_serving() {
    let _g = faults_guard();
    fault::clear();
    fault::silence_injected_panics();
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        tight_policy(),
        workers(2),
        1,
    )
    .unwrap();
    let addr = srv.addr;
    let metrics = srv.metrics.clone();
    let client = thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        // clean request first: proves health and finishes warmup, so the
        // blackout below cannot race engine construction
        let (class, _) = c.infer(1).unwrap();
        assert!(class < 10);
        fault::install(FaultSpec { seed: 3, panic_per_mille: 1000, ..FaultSpec::default() });
        let reply = c.request(None, 2).unwrap();
        assert_eq!(reply, Reply::Err("ERR internal inference-failed".into()));
        fault::clear();
        // the SAME connection and the SAME shard thread still serve
        let (class, _) = c.infer(3).unwrap();
        assert!(class < 10);
        let stats = c.stats().unwrap();
        assert!(stats.contains("internal=1"), "per-code counter missing: {stats}");
    });
    srv.serve_while(Duration::from_secs(30), || client.is_finished()).unwrap();
    client.join().unwrap();
    assert!(
        metrics.panics_caught.load(Ordering::Relaxed) >= 1,
        "the panic must be caught, not fatal"
    );
    assert_eq!(metrics.errors.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.responses.load(Ordering::Relaxed), 2);
    srv.shutdown();
}

#[test]
fn shard_quarantines_after_consecutive_failures_and_recovers() {
    let _g = faults_guard();
    fault::clear();
    fault::silence_injected_panics();
    let hp = HealthPolicy { quarantine_after: 2, rebuild_backoff: Duration::from_millis(2) };
    let pool = ShardPool::start_with_health(
        "tinycnn",
        Backend::Sim,
        tight_policy(),
        workers(1),
        1,
        hp,
    )
    .unwrap();
    // healthy baseline
    assert!(matches!(roundtrip(&pool, 1), Ok(ShardReply::Ok { .. })));
    assert_eq!(pool.metrics.health[0].state(), HealthState::Healthy);

    // blackout: every chunk panics → each batch fails, replies ERR
    fault::install(FaultSpec { seed: 5, panic_per_mille: 1000, ..FaultSpec::default() });
    for seed in [2u64, 3] {
        match roundtrip(&pool, seed) {
            Ok(ShardReply::Err(ErrCode::Internal)) => {}
            other => panic!("expected ERR internal under blackout, got {other:?}"),
        }
    }
    // two consecutive failures trip quarantine; admission starts bouncing
    let t0 = Instant::now();
    loop {
        match roundtrip(&pool, 99) {
            Err(Admission::Unhealthy) => break,
            Ok(_) => {} // raced the trip; queued job was answered, retry
            Err(other) => panic!("unexpected admission {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "pool never quarantined");
    }
    assert_eq!(pool.metrics.quarantines.load(Ordering::Relaxed), 1);
    assert_eq!(pool.metrics.health[0].state(), HealthState::Quarantined);
    let summary = pool.metrics.summary();
    assert!(summary.contains("health=[s0: quarantined]"), "{summary}");

    // faults stop → the supervisor rebuilds, self-tests, readmits
    fault::clear();
    let t0 = Instant::now();
    loop {
        match roundtrip(&pool, 7) {
            Ok(ShardReply::Ok { .. }) => break,
            Ok(ShardReply::Err(_)) | Err(Admission::Unhealthy) => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(other) => panic!("unexpected admission {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "shard never recovered");
    }
    assert_eq!(pool.metrics.recoveries.load(Ordering::Relaxed), 1);
    assert_eq!(pool.metrics.health[0].state(), HealthState::Healthy);
    assert!(pool.metrics.health[0].quarantine_ns() > 0, "episode must be timed");
    pool.drain();
}

#[test]
fn deadline_refused_up_front_without_executing() {
    let _g = faults_guard();
    fault::clear();
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1), ..Default::default() },
        workers(1),
        1,
    )
    .unwrap();
    let addr = srv.addr;
    let metrics = srv.metrics.clone();
    let client = thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        // zero budget: the plan-predicted cost can never fit → refused
        // before any queueing or execution
        let reply = c.request_deadline(None, 5, Duration::ZERO).unwrap();
        assert_eq!(reply, Reply::Busy("deadline".into()));
        // a generous budget sails through on the same connection
        let reply = c.request_deadline(None, 5, Duration::from_secs(5)).unwrap();
        assert!(matches!(reply, Reply::Ok { .. }), "{reply:?}");
        let stats = c.stats().unwrap();
        assert!(stats.contains("busy_deadline=1"), "{stats}");
    });
    srv.serve_while(Duration::from_secs(30), || client.is_finished()).unwrap();
    client.join().unwrap();
    assert_eq!(metrics.dropped_deadline.load(Ordering::Relaxed), 1);
    // refused means *not executed*: one response (the OK), zero errors
    assert_eq!(metrics.responses.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.errors.load(Ordering::Relaxed), 0);
    srv.shutdown();
}

#[test]
fn stalled_connection_is_reaped_while_live_ones_serve() {
    let _g = faults_guard();
    fault::clear();
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        tight_policy(),
        workers(1),
        1,
    )
    .unwrap();
    srv.set_conn_policy(ConnPolicy {
        idle: Duration::from_millis(150),
        write: Duration::from_secs(2),
    });
    let addr = srv.addr;
    let metrics = srv.metrics.clone();
    let client = thread::spawn(move || {
        // stalled: connects and never sends a byte
        let stalled = TcpStream::connect(addr).unwrap();
        // live: keeps requesting straight through the reap window
        let mut c = Client::connect(addr).unwrap();
        for i in 0..4u64 {
            let (class, _) = c.infer(i).unwrap();
            assert!(class < 10);
            thread::sleep(Duration::from_millis(60));
        }
        // the reaper must have closed the stalled socket: EOF, not hang
        stalled.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 8];
        let n = (&stalled).read(&mut buf).unwrap();
        assert_eq!(n, 0, "server must close the reaped connection");
    });
    srv.serve_while(Duration::from_secs(30), || client.is_finished()).unwrap();
    client.join().unwrap();
    assert!(
        metrics.reaped_conns.load(Ordering::Relaxed) >= 1,
        "idle connection must be reaped: {}",
        metrics.summary()
    );
    srv.shutdown();
}
