//! Packed LUT-GEMM pins: the planner-routed GEMM conv path must be
//! bit-exact against `exec::conv2d` (the reference executor) across
//! random shapes, strides, thread counts and substrates, with requant
//! folded into the tile epilogue; and the panel packers must round-trip
//! against the naive gather on ragged edges (K not a multiple of the
//! panel width, fewer output pixels than the tile height, channels=1).
//!
//! Bit-exactness is the whole contract: the GEMM-vs-row choice is pure
//! performance (see `dataflow::gemm`), so any diverging bit is a bug.

use neuromax::dataflow::engine::{encode_cols, fuse_row, FusedWeights};
use neuromax::dataflow::{
    exec, pack_cols, pack_weight_panels, plan_rows_gemm, Engine, SwCost, WorkerPool, GEMM_NR,
};
use neuromax::lns::logquant::ZERO_CODE;
use neuromax::lns::tables::requant_act;
use neuromax::tensor::{out_dim, Tensor3, Tensor4};
use neuromax::util::prng::SplitMix64;
use neuromax::util::proptest::check;

fn rand_t3(rng: &mut SplitMix64, h: usize, w: usize, c: usize) -> Tensor3 {
    let mut t = Tensor3::new(h, w, c);
    for v in t.data.iter_mut() {
        *v = if rng.bool(0.15) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    t
}

fn rand_t4(rng: &mut SplitMix64, k: usize, kh: usize, kw: usize, c: usize) -> (Tensor4, Tensor4) {
    let mut wc = Tensor4::new(k, kh, kw, c);
    let mut ws = Tensor4::new(k, kh, kw, c);
    for v in wc.data.iter_mut() {
        *v = if rng.bool(0.15) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }
    (wc, ws)
}

#[test]
fn gemm_path_is_bit_exact_vs_exec_across_random_shapes() {
    let pool = WorkerPool::new(3);
    check("gemm-vs-exec", 40, |rng| {
        let kh = [1usize, 2, 3, 5][rng.below(4) as usize];
        let kw = if rng.bool(0.8) { kh } else { 1 + rng.below(4) as usize };
        let stride = 1 + rng.below(2) as usize;
        let c = 1 + rng.below(6) as usize; // includes channels = 1
        let k = 1 + rng.below(9) as usize; // ragged vs the NR=4 panels
        // small heights sometimes leave fewer pixels than a full tile
        let h = kh.max(kw) + rng.below(12) as usize;
        let w = kh.max(kw) + rng.below(12) as usize;
        let a = rand_t3(rng, h, w, c);
        let (wc, ws) = rand_t4(rng, k, kh, kw, c);
        let want = exec::conv2d(&a, &wc, &ws, stride);
        let fw = FusedWeights::fuse(&wc, &ws);
        let mut cols = Vec::new();
        encode_cols(&a.data, &mut cols);
        let (ho, wo) = (out_dim(h, kh, stride), out_dim(w, kw, stride));
        let work = (ho * wo * k * kh * kw * c) as u64;
        for eng in [
            Engine::single_threaded(),
            Engine::with_threads(3),
            Engine::pooled_forced(pool.clone()),
        ] {
            for forced in [false, true] {
                let plan = plan_rows_gemm(
                    ho,
                    work,
                    wo,
                    fw.kdim(),
                    eng.num_threads(),
                    &SwCost::pooled(),
                    forced,
                );
                let tile = plan.gemm.clone().expect("gemm plan carries a tile");
                neuromax::prop_assert!(
                    tile.nr == GEMM_NR && [1, 2, 4].contains(&tile.mr),
                    "bad tile {}x{}",
                    tile.mr,
                    tile.nr
                );
                let mut scratch = vec![0u8; tile.scratch_len];
                for requant in [false, true] {
                    let mut got = vec![7i32; want.len()];
                    eng.conv2d_gemm_plan(
                        &cols,
                        h,
                        w,
                        &fw,
                        stride,
                        &mut got,
                        &plan,
                        &tile,
                        requant,
                        None,
                        &mut scratch,
                    );
                    let mut expect = want.data.clone();
                    if requant {
                        for v in expect.iter_mut() {
                            *v = requant_act(*v);
                        }
                    }
                    neuromax::prop_assert!(
                        got == expect,
                        "GEMM diverged: h={h} w={w} c={c} k={k} kh={kh} kw={kw} \
                         stride={stride} threads={} forced={forced} requant={requant}",
                        eng.num_threads()
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn panel_packers_round_trip_against_the_naive_gather() {
    check("panel-pack-round-trip", 80, |rng| {
        // ---- weight panels: ragged K against the naive row layout ----
        let k = 1 + rng.below(11) as usize;
        let kh = 1 + rng.below(4) as usize;
        let kw = 1 + rng.below(4) as usize;
        let c = 1 + rng.below(5) as usize;
        let kdim = kh * kw * c;
        let rows: Vec<u8> = (0..k * kdim)
            .map(|_| {
                if rng.bool(0.2) {
                    0
                } else {
                    fuse_row(rng.range_i32(-12, 8), rng.sign())
                }
            })
            .collect();
        let p = pack_weight_panels(&rows, k, kdim);
        neuromax::prop_assert!(
            p.nr == GEMM_NR && p.k == k && p.kdim == kdim,
            "panel header mismatch (k={k} kdim={kdim})"
        );
        let padded_k = k.div_ceil(GEMM_NR) * GEMM_NR;
        neuromax::prop_assert!(
            p.data.len() == padded_k * kdim,
            "panel bytes {} != {padded_k}·{kdim}",
            p.data.len()
        );
        for f in 0..padded_k {
            for t in 0..kdim {
                let got = p.data[(f / GEMM_NR) * GEMM_NR * kdim + t * GEMM_NR + f % GEMM_NR];
                let want = if f < k { rows[f * kdim + t] } else { 0 };
                neuromax::prop_assert!(
                    got == want,
                    "weight panel (filter {f}, tap {t}) = {got}, want {want} (k={k})"
                );
            }
        }
        // ---- pixel panels: ragged pixel tails, c=1, strides ----
        let stride = 1 + rng.below(2) as usize;
        let h = kh.max(kw) + rng.below(8) as usize;
        let w = kh.max(kw) + rng.below(8) as usize;
        let a = rand_t3(rng, h, w, c);
        let mut cols = Vec::new();
        encode_cols(&a.data, &mut cols);
        let (ho, wo) = (out_dim(h, kh, stride), out_dim(w, kw, stride));
        let npix = ho * wo;
        let mr = [1usize, 2, 4][rng.below(3) as usize];
        let mut dst = vec![0xAAu8; npix.div_ceil(mr) * mr * kdim];
        pack_cols(&cols, w, c, kh, kw, stride, wo, 0, npix, mr, &mut dst);
        for pb in 0..npix.div_ceil(mr) {
            for lane in 0..mr {
                let pix = pb * mr + lane;
                for t in 0..kdim {
                    let got = dst[pb * mr * kdim + t * mr + lane];
                    let want = if pix < npix {
                        // naive gather: decode (pixel, tap) -> input byte
                        let (i, j) = (pix / wo, pix % wo);
                        let (dy, rest) = (t / (kw * c), t % (kw * c));
                        let (dx, ch) = (rest / c, rest % c);
                        cols[((i * stride + dy) * w + j * stride + dx) * c + ch]
                    } else {
                        0 // dead lane must pack the zero column
                    };
                    neuromax::prop_assert!(
                        got == want,
                        "pixel panel (pix {pix}, tap {t}) = {got}, want {want} \
                         (h={h} w={w} c={c} kh={kh} kw={kw} stride={stride} mr={mr})"
                    );
                }
            }
        }
        Ok(())
    });
}
