//! Packed LUT-GEMM pins: the planner-routed GEMM conv path must be
//! bit-exact against `exec::conv2d` (the reference executor) across
//! random shapes, strides, thread counts and substrates, with requant
//! folded into the tile epilogue — for the micro-kernel of **every**
//! arch kernel table this process can resolve (the detected table AND
//! the portable scalar table, so a SIMD machine still pins the scalar
//! fallback it would run under `NEUROMAX_FORCE_SCALAR=1`). The panel
//! packers must round-trip against the naive gather at each table NR on
//! ragged edges (K not a multiple of the panel width, fewer output
//! pixels than the tile height, channels=1), and degenerate packs are a
//! typed error, never a silent all-zero panel.
//!
//! Bit-exactness is the whole contract: the GEMM-vs-row choice and the
//! scalar-vs-SIMD choice are pure performance (see `dataflow::gemm`),
//! so any diverging bit is a bug.

use neuromax::dataflow::engine::{encode_cols, fuse_row, FusedWeights};
use neuromax::dataflow::{
    exec, kernel_table, pack_cols, pack_weight_panels, plan_gemm_tile_with, plan_rows_gemm,
    scalar_table, Engine, PackError, SwCost, WorkerPool, GEMM_NR,
};
use neuromax::lns::logquant::ZERO_CODE;
use neuromax::lns::tables::requant_act;
use neuromax::tensor::{out_dim, Tensor3, Tensor4};
use neuromax::util::prng::SplitMix64;
use neuromax::util::proptest::check;

fn rand_t3(rng: &mut SplitMix64, h: usize, w: usize, c: usize) -> Tensor3 {
    let mut t = Tensor3::new(h, w, c);
    for v in t.data.iter_mut() {
        *v = if rng.bool(0.15) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    t
}

fn rand_t4(rng: &mut SplitMix64, k: usize, kh: usize, kw: usize, c: usize) -> (Tensor4, Tensor4) {
    let mut wc = Tensor4::new(k, kh, kw, c);
    let mut ws = Tensor4::new(k, kh, kw, c);
    for v in wc.data.iter_mut() {
        *v = if rng.bool(0.15) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }
    (wc, ws)
}

#[test]
fn gemm_path_is_bit_exact_vs_exec_across_random_shapes() {
    let pool = WorkerPool::new(3);
    check("gemm-vs-exec", 40, |rng| {
        let kh = [1usize, 2, 3, 5][rng.below(4) as usize];
        let kw = if rng.bool(0.8) { kh } else { 1 + rng.below(4) as usize };
        let stride = 1 + rng.below(2) as usize;
        let c = 1 + rng.below(6) as usize; // includes channels = 1
        let k = 1 + rng.below(9) as usize; // ragged vs the NR=4 panels
        // small heights sometimes leave fewer pixels than a full tile
        let h = kh.max(kw) + rng.below(12) as usize;
        let w = kh.max(kw) + rng.below(12) as usize;
        let a = rand_t3(rng, h, w, c);
        let (wc, ws) = rand_t4(rng, k, kh, kw, c);
        let want = exec::conv2d(&a, &wc, &ws, stride);
        let fw = FusedWeights::fuse(&wc, &ws);
        let mut cols = Vec::new();
        encode_cols(&a.data, &mut cols);
        let (ho, wo) = (out_dim(h, kh, stride), out_dim(w, kw, stride));
        let work = (ho * wo * k * kh * kw * c) as u64;
        for eng in [
            Engine::single_threaded(),
            Engine::with_threads(3),
            Engine::pooled_forced(pool.clone()),
        ] {
            for forced in [false, true] {
                let plan = plan_rows_gemm(
                    ho,
                    work,
                    wo,
                    fw.kdim(),
                    eng.num_threads(),
                    &SwCost::pooled(),
                    forced,
                );
                // differential sweep: the detected arch table (what the
                // planner actually picked — the plan's own tile) AND the
                // portable scalar table, so SIMD machines also pin their
                // forced-scalar fallback against the reference executor
                let mut tables = vec![kernel_table()];
                if kernel_table().arch != "scalar" {
                    tables.push(scalar_table());
                }
                for table in tables {
                    let tile = if std::ptr::eq(table, kernel_table()) {
                        plan.gemm.clone().expect("gemm plan carries a tile")
                    } else {
                        plan_gemm_tile_with(table, &plan.chunks, ho, wo, fw.kdim())
                    };
                    neuromax::prop_assert!(
                        table
                            .tiles
                            .iter()
                            .any(|&(m, n, kn)| (m, n, kn) == (tile.mr, tile.nr, tile.kernel)),
                        "tile {}x{} {:?} is not an entry of the {} table",
                        tile.mr,
                        tile.nr,
                        tile.kernel,
                        table.arch
                    );
                    let mut scratch = vec![0u8; tile.scratch_len];
                    for requant in [false, true] {
                        let mut got = vec![7i32; want.len()];
                        eng.conv2d_gemm_plan(
                            &cols,
                            h,
                            w,
                            &fw,
                            stride,
                            &mut got,
                            &plan,
                            &tile,
                            requant,
                            None,
                            &mut scratch,
                        );
                        let mut expect = want.data.clone();
                        if requant {
                            for v in expect.iter_mut() {
                                *v = requant_act(*v);
                            }
                        }
                        neuromax::prop_assert!(
                            got == expect,
                            "GEMM diverged: h={h} w={w} c={c} k={k} kh={kh} kw={kw} \
                             stride={stride} threads={} forced={forced} requant={requant} \
                             tile={}x{} {:?} ({})",
                            eng.num_threads(),
                            tile.mr,
                            tile.nr,
                            tile.kernel,
                            table.arch
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn panel_packers_round_trip_against_the_naive_gather() {
    check("panel-pack-round-trip", 80, |rng| {
        // ---- weight panels: ragged K against the naive row layout ----
        let k = 1 + rng.below(11) as usize;
        let kh = 1 + rng.below(4) as usize;
        let kw = 1 + rng.below(4) as usize;
        let c = 1 + rng.below(5) as usize;
        let kdim = kh * kw * c;
        let rows: Vec<u8> = (0..k * kdim)
            .map(|_| {
                if rng.bool(0.2) {
                    0
                } else {
                    fuse_row(rng.range_i32(-12, 8), rng.sign())
                }
            })
            .collect();
        // every NR any kernel table can plan (scalar 4, SIMD 8), plus
        // the legacy GEMM_NR default, deduped
        let mut nrs: Vec<usize> = kernel_table()
            .tiles
            .iter()
            .chain(scalar_table().tiles)
            .map(|&(_, n, _)| n)
            .chain([GEMM_NR])
            .collect();
        nrs.sort_unstable();
        nrs.dedup();
        for &nr in &nrs {
            let p = pack_weight_panels(&rows, k, kdim, nr).expect("non-degenerate pack");
            neuromax::prop_assert!(
                p.nr == nr && p.k == k && p.kdim == kdim,
                "panel header mismatch (k={k} kdim={kdim} nr={nr})"
            );
            let padded_k = k.div_ceil(nr) * nr;
            neuromax::prop_assert!(
                p.data.len() == padded_k * kdim,
                "panel bytes {} != {padded_k}·{kdim} (nr={nr})",
                p.data.len()
            );
            for f in 0..padded_k {
                for t in 0..kdim {
                    let got = p.data[(f / nr) * nr * kdim + t * nr + f % nr];
                    let want = if f < k { rows[f * kdim + t] } else { 0 };
                    neuromax::prop_assert!(
                        got == want,
                        "weight panel (filter {f}, tap {t}) = {got}, want {want} \
                         (k={k} nr={nr})"
                    );
                }
            }
        }
        // ---- pixel panels: ragged pixel tails, c=1, strides ----
        let stride = 1 + rng.below(2) as usize;
        let h = kh.max(kw) + rng.below(8) as usize;
        let w = kh.max(kw) + rng.below(8) as usize;
        let a = rand_t3(rng, h, w, c);
        let mut cols = Vec::new();
        encode_cols(&a.data, &mut cols);
        let (ho, wo) = (out_dim(h, kh, stride), out_dim(w, kw, stride));
        let npix = ho * wo;
        let mr = [1usize, 2, 4, 8][rng.below(4) as usize];
        let mut dst = vec![0xAAu8; npix.div_ceil(mr) * mr * kdim];
        pack_cols(&cols, w, c, kh, kw, stride, wo, 0, npix, mr, &mut dst);
        for pb in 0..npix.div_ceil(mr) {
            for lane in 0..mr {
                let pix = pb * mr + lane;
                for t in 0..kdim {
                    let got = dst[pb * mr * kdim + t * mr + lane];
                    let want = if pix < npix {
                        // naive gather: decode (pixel, tap) -> input byte
                        let (i, j) = (pix / wo, pix % wo);
                        let (dy, rest) = (t / (kw * c), t % (kw * c));
                        let (dx, ch) = (rest / c, rest % c);
                        cols[((i * stride + dy) * w + j * stride + dx) * c + ch]
                    } else {
                        0 // dead lane must pack the zero column
                    };
                    neuromax::prop_assert!(
                        got == want,
                        "pixel panel (pix {pix}, tap {t}) = {got}, want {want} \
                         (h={h} w={w} c={c} kh={kh} kw={kw} stride={stride} mr={mr})"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn degenerate_weight_packs_are_typed_errors() {
    // k == 0 / kdim == 0 must surface as a PackError, never as a silent
    // all-zero panel the micro-kernel would happily consume
    assert_eq!(pack_weight_panels(&[], 0, 9, GEMM_NR), Err(PackError::ZeroFilters));
    assert_eq!(pack_weight_panels(&[], 3, 0, GEMM_NR), Err(PackError::ZeroDepth));
    assert_eq!(pack_weight_panels(&[], 0, 0, 8), Err(PackError::ZeroFilters), "k wins ties");
    // the error is a real std::error::Error with a useful message
    let e: Box<dyn std::error::Error> = Box::new(PackError::ZeroDepth);
    assert!(e.to_string().contains("kdim"), "{e}");
    // and the smallest valid pack still succeeds at every table NR
    for &(_, nr, _) in kernel_table().tiles.iter().chain(scalar_table().tiles) {
        let p = pack_weight_panels(&[fuse_row(1, 1)], 1, 1, nr).expect("1x1 pack");
        assert_eq!(p.data.len(), nr, "one padded panel of {nr} filters");
    }
}
