//! Typed-IR semantics pins: lowering, the rewrite-pass pipeline, and the
//! graph program compiler.
//!
//! Every pass is a pure `Graph -> Graph` rewrite with a machine-checkable
//! contract: the rewritten graph re-validates, evaluates bit-identically
//! under the reference interpreter (`ir::reference_forward`), and the
//! pass is idempotent. The full pipeline's output must then compile into
//! a `ModelProgram` that executes bit-identically on one thread and on a
//! forced-parallel worker pool — over random zoo-like flat nets *and*
//! random builder graphs with shapes the flat layer-list language cannot
//! express (diamond fan-out, nested concats, shared merge values).
//!
//! Graph generators and the slot-provenance replay are shared with
//! `program_slots.rs` via `common::graphgen`.

mod common;

use std::sync::Arc;

use common::graphgen::{
    check_slot_provenance, diamond_graph, random_graph, random_net, stage_graph,
};
use neuromax::coordinator::InferenceEngine;
use neuromax::dataflow::forward::{forward_ref, ForwardPlan, Routing};
use neuromax::dataflow::program::{Input, Kernel, Merge, ModelProgram, ProgramExecutor};
use neuromax::dataflow::workers::WorkerPool;
use neuromax::dataflow::{
    default_pipeline, reference_forward, run_pipeline, Engine, EngineOptions, Graph, GraphError,
    NodeOp,
};
use neuromax::models::layer::{LayerDesc, Network, Op};
use neuromax::models::runner::{random_input_dims, random_input_for, NetWeights};
use neuromax::models::workload;
use neuromax::util::proptest::check;

/// Random input sized for a graph's input node.
fn input_for_graph(g: &Graph, seed: u64) -> neuromax::tensor::Tensor3 {
    let s = g.nodes[0].shape;
    random_input_dims(s.h, s.w, s.c, seed)
}

// ---------------------------------------------------------------------
// Lowering
// ---------------------------------------------------------------------

#[test]
fn lowered_zoo_graphs_validate_and_match_the_legacy_reference() {
    for name in workload::ZOO_NAMES {
        let net = workload::test_profile(name).unwrap();
        let g = Graph::lower(&net).unwrap_or_else(|e| panic!("{}: {e}", net.name));
        g.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
        let w = NetWeights::random(&net, 0xA11CE ^ g.layers.len() as u64);
        let x = random_input_for(&net, 0xB0B);
        let got = reference_forward(&g, &w, &x);
        let want = forward_ref(&net, &w, &x);
        assert_eq!(got.data, want.data, "{}: IR interpreter != legacy reference", net.name);
    }
}

#[test]
fn malformed_layer_lists_fail_fast_with_typed_errors() {
    // Each of these used to panic deep in execution (out_dims asserts,
    // exec channel mismatches) or route nonsense; lowering now rejects
    // them up front with a typed error, and `ForwardPlan::infer`
    // surfaces it as a plan failure instead of a panic.
    let empty = Network { name: "empty".into(), layers: vec![] };
    assert!(matches!(Graph::lower(&empty), Err(GraphError::Empty)));
    assert!(ForwardPlan::infer(&empty).is_err());

    let zero_dim = Network {
        name: "zero-dim".into(),
        layers: vec![LayerDesc::conv("z", 3, 1, 1, 0, 8, 3, 4)],
    };
    assert!(matches!(
        Graph::lower(&zero_dim),
        Err(GraphError::ZeroDim { layer: 0, .. })
    ));

    let zero_stride = Network {
        name: "zero-stride".into(),
        layers: vec![LayerDesc {
            name: "s0".into(),
            op: Op::Conv { kh: 3, kw: 3, stride: 0, pad: 1 },
            hin: 8,
            win: 8,
            cin: 3,
            cout: 4,
        }],
    };
    assert!(matches!(
        Graph::lower(&zero_stride),
        Err(GraphError::ZeroStride { layer: 0, .. })
    ));

    let big_kernel = Network {
        name: "big-kernel".into(),
        layers: vec![LayerDesc::conv("k", 5, 1, 0, 2, 2, 3, 4)],
    };
    assert!(matches!(
        Graph::lower(&big_kernel),
        Err(GraphError::KernelTooLarge { layer: 0, .. })
    ));

    let chan_mismatch = Network {
        name: "dw-mismatch".into(),
        layers: vec![LayerDesc {
            name: "dw".into(),
            op: Op::Depthwise { k: 3, stride: 1, pad: 1 },
            hin: 8,
            win: 8,
            cin: 4,
            cout: 5,
        }],
    };
    assert!(matches!(
        Graph::lower(&chan_mismatch),
        Err(GraphError::ChannelMismatch { layer: 0, .. })
    ));

    let no_producer = Network {
        name: "no-producer".into(),
        layers: vec![
            LayerDesc::conv("c0", 3, 1, 1, 8, 8, 3, 4),
            LayerDesc::conv("c1", 3, 1, 1, 8, 8, 9, 4),
        ],
    };
    assert!(matches!(
        Graph::lower(&no_producer),
        Err(GraphError::NoProducer { layer: 1, .. })
    ));
    assert!(ForwardPlan::infer(&no_producer).is_err());

    let no_flat = Network {
        name: "no-flat".into(),
        layers: vec![
            LayerDesc::conv("c0", 3, 1, 1, 8, 8, 3, 4),
            LayerDesc::fc("fc", 999, 5),
        ],
    };
    assert!(matches!(
        Graph::lower(&no_flat),
        Err(GraphError::NoFlatProducer { layer: 1, need: 999, .. })
    ));
}

// ---------------------------------------------------------------------
// Per-pass contracts
// ---------------------------------------------------------------------

/// Run the pipeline pass by pass, pinning each one's contract: the
/// rewritten graph re-validates, evaluates bit-identically, and the pass
/// is idempotent. Cumulative (each pass sees its predecessors' output),
/// matching how `run_pipeline` actually composes them.
fn check_pass_contracts(g: &Graph, w: &NetWeights, x: &neuromax::tensor::Tensor3) -> Result<(), String> {
    let want = reference_forward(g, w, x);
    let mut cur = g.clone();
    for p in default_pipeline() {
        let next = (p.run)(&cur);
        next.validate()
            .map_err(|e| format!("{}: pass {} broke validation: {e}", g.name, p.name))?;
        let got = reference_forward(&next, w, x);
        neuromax::prop_assert!(
            got.data == want.data,
            "{}: pass {} changed semantics",
            g.name,
            p.name
        );
        let again = (p.run)(&next);
        neuromax::prop_assert!(again == next, "{}: pass {} is not idempotent", g.name, p.name);
        cur = next;
    }
    neuromax::prop_assert!(
        cur.nodes.iter().all(|nd| nd.op != NodeOp::Requant),
        "{}: pipeline left explicit requant nodes",
        g.name
    );
    Ok(())
}

#[test]
fn passes_preserve_reference_semantics_on_lowered_flat_nets() {
    check("pass-semantics-flat", 20, |rng| {
        let tag = rng.next_u64() & 0xFFFF;
        let net = random_net(rng, tag);
        let g = Graph::lower(&net).map_err(|e| format!("{}: {e}", net.name))?;
        let w = NetWeights::random(&net, rng.next_u64());
        let x = random_input_for(&net, rng.next_u64());
        check_pass_contracts(&g, &w, &x)
    });
}

#[test]
fn passes_preserve_reference_semantics_on_builder_graphs() {
    check("pass-semantics-graph", 20, |rng| {
        let tag = rng.next_u64() & 0xFFFF;
        let g = random_graph(rng, tag);
        let w = NetWeights::random(&g.weight_network(), rng.next_u64());
        let x = input_for_graph(&g, rng.next_u64());
        check_pass_contracts(&g, &w, &x)
    });
}

// ---------------------------------------------------------------------
// Individual rewrites, pinned on deterministic fixtures
// ---------------------------------------------------------------------

#[test]
fn dead_node_elimination_shrinks_the_compiled_program() {
    // an orphan layer (routable, consumed by nothing) still executes on
    // the legacy flat path but is swept by the IR pipeline — the whole
    // point of compiling through the graph
    let net = Network {
        name: "orphaned".into(),
        layers: vec![
            LayerDesc::conv("c0", 3, 1, 1, 8, 8, 3, 4),
            LayerDesc::pointwise("dead", 8, 8, 4, 40),
            LayerDesc::conv("c2", 3, 1, 1, 8, 8, 4, 5),
        ],
    };
    let plan = ForwardPlan::infer(&net).unwrap();
    let flat = ModelProgram::from_plan(&net, &plan);
    assert_eq!(flat.steps.len(), 3, "flat path executes the orphan");
    let prog = ModelProgram::compile(&net).unwrap();
    assert_eq!(prog.steps.len(), 2, "IR pipeline sweeps the orphan");

    // and the two programs still agree on the served output
    let w = NetWeights::random(&net, 0xD15EA5E);
    let fused = w.fuse();
    let x = random_input_for(&net, 0xF00D);
    let eng = Engine::single_threaded();
    let a = ProgramExecutor::new(Arc::new(flat)).run(&eng, &fused, &x);
    let b = ProgramExecutor::new(Arc::new(prog)).run(&eng, &fused, &x);
    assert_eq!(a.data, b.data, "orphan elimination changed the output");
}

#[test]
fn one_by_one_convs_over_flat_maps_compile_as_fc() {
    let net = Network {
        name: "fc-tail".into(),
        layers: vec![
            LayerDesc::conv("c0", 3, 1, 1, 6, 6, 3, 4),
            LayerDesc::fc("fc0", 6 * 6 * 4, 5),
            LayerDesc::pointwise("head", 1, 1, 5, 3),
        ],
    };
    let g = Graph::lower(&net).unwrap();
    let piped = run_pipeline(&g, &default_pipeline()).unwrap();
    let fc_nodes = piped.nodes.iter().filter(|nd| nd.op == NodeOp::Fc).count();
    assert_eq!(fc_nodes, 2, "pointwise head over a 1x1 map should retag as fc");
    assert_eq!(piped.layers[2].op, Op::Fc, "descriptor retagged for the planner");

    let prog = ModelProgram::from_graph(&piped).unwrap();
    assert_eq!(
        prog.steps.iter().filter(|s| s.kernel == Kernel::Fc).count(),
        2,
        "both tail steps cost as Fc"
    );
    // bit-exact vs the legacy path on the original descriptors (weight
    // shapes are identical: pointwise and fc both draw (cout,1,1,cin))
    let w = NetWeights::random(&net, 0xFC);
    let x = random_input_for(&net, 0x5EED);
    let want = forward_ref(&net, &w, &x);
    let got = ProgramExecutor::new(Arc::new(prog)).run(
        &Engine::single_threaded(),
        &w.fuse(),
        &x,
    );
    assert_eq!(got.data, want.data, "fc retag changed numerics");
}

#[test]
fn nested_concats_fold_to_one_nary_staged_merge() {
    let mut b = neuromax::dataflow::GraphBuilder::new("nested", 6, 6, 2);
    let a = b.conv(b.input(), 3, 1, 1, 2).unwrap();
    let p = b.pointwise(a, 1).unwrap();
    let q = b.pointwise(a, 2).unwrap();
    let r = b.depthwise(a, 1).unwrap();
    let inner = b.concat(&[p, q]).unwrap();
    let outer = b.concat(&[inner, r]).unwrap();
    let out = b.pointwise(outer, 4).unwrap();
    let g = b.finish(out).unwrap();

    let piped = run_pipeline(&g, &default_pipeline()).unwrap();
    let concats: Vec<_> =
        piped.nodes.iter().filter(|nd| nd.op == NodeOp::Concat).collect();
    assert_eq!(concats.len(), 1, "back-to-back concats should elide to one");
    assert_eq!(concats[0].inputs.len(), 3, "the survivor is n-ary");

    let prog = ModelProgram::from_graph(&piped).unwrap();
    check_slot_provenance(&prog).unwrap();
    let nary = prog.steps.iter().any(|s| {
        matches!(&s.input, Input::Staged(sp)
            if matches!(&sp.merge, Merge::Concat(parts) if parts.len() == 3))
    });
    assert!(nary, "program should stage the concat as one 3-way merge");

    let w = NetWeights::random(&piped.weight_network(), 0xCAFE);
    let x = input_for_graph(&piped, 0xBEEF);
    let want = reference_forward(&piped, &w, &x);
    let got = ProgramExecutor::new(Arc::new(prog)).run(
        &Engine::single_threaded(),
        &w.fuse(),
        &x,
    );
    assert_eq!(got.data, want.data, "n-ary staging changed numerics");
}

#[test]
fn shared_merge_values_materialize_as_stage_steps() {
    // a concat read by TWO kernel consumers cannot fold into either —
    // the program compiler must emit an explicit Stage step, and both
    // consumers must read the staged value after the stage's own slot
    // traffic (covered by the provenance replay)
    let g = stage_graph();
    let piped = run_pipeline(&g, &default_pipeline()).unwrap();
    let prog = ModelProgram::from_graph(&piped).unwrap();
    check_slot_provenance(&prog).unwrap();
    assert!(
        prog.steps.iter().any(|s| s.kernel == Kernel::Stage),
        "shared concat should materialize as a Stage step"
    );

    let pool = WorkerPool::new(3);
    let w = NetWeights::random(&piped.weight_network(), 0x57A6E);
    let fused = w.fuse();
    let x = input_for_graph(&piped, 0x1DEA);
    let want = reference_forward(&piped, &w, &x);
    let prog = Arc::new(prog);
    let serial = ProgramExecutor::new(prog.clone()).run(&Engine::single_threaded(), &fused, &x);
    assert_eq!(serial.data, want.data, "staged execution (serial) != reference");
    let pooled =
        ProgramExecutor::new(prog).run(&Engine::pooled_forced(pool), &fused, &x);
    assert_eq!(pooled.data, want.data, "staged execution (pooled) != reference");
}

// ---------------------------------------------------------------------
// Full pipeline → program equivalence
// ---------------------------------------------------------------------

#[test]
fn pipeline_programs_stay_bit_exact_on_random_flat_nets() {
    let pool = WorkerPool::new(3);
    check("ir-pipeline-flat", 20, |rng| {
        let tag = rng.next_u64() & 0xFFFF;
        let net = random_net(rng, tag);
        let g = Graph::lower(&net).map_err(|e| format!("{}: {e}", net.name))?;
        let piped = run_pipeline(&g, &default_pipeline())
            .map_err(|e| format!("{}: pipeline: {e}", net.name))?;
        let prog = ModelProgram::from_graph(&piped)
            .map_err(|e| format!("{}: from_graph: {e}", net.name))?;
        check_slot_provenance(&prog)?;

        let w = NetWeights::random(&net, rng.next_u64());
        let fused = w.fuse();
        let x = random_input_for(&net, rng.next_u64());
        let want = forward_ref(&net, &w, &x);
        let ir_ref = reference_forward(&piped, &w, &x);
        neuromax::prop_assert!(
            ir_ref.data == want.data,
            "{}: IR reference != legacy reference",
            net.name
        );
        let prog = Arc::new(prog);
        let serial =
            ProgramExecutor::new(prog.clone()).run(&Engine::single_threaded(), &fused, &x);
        neuromax::prop_assert!(
            serial.data == want.data,
            "{}: graph program (serial) != reference",
            net.name
        );
        let pooled =
            ProgramExecutor::new(prog).run(&Engine::pooled_forced(pool.clone()), &fused, &x);
        neuromax::prop_assert!(
            pooled.data == want.data,
            "{}: graph program (pooled) != reference",
            net.name
        );
        Ok(())
    });
}

#[test]
fn pipeline_programs_stay_bit_exact_on_random_builder_graphs() {
    let pool = WorkerPool::new(4);
    check("ir-pipeline-graph", 20, |rng| {
        let tag = rng.next_u64() & 0xFFFF;
        let g = random_graph(rng, tag);
        let piped = run_pipeline(&g, &default_pipeline())
            .map_err(|e| format!("{}: pipeline: {e}", g.name))?;
        let prog = ModelProgram::from_graph(&piped)
            .map_err(|e| format!("{}: from_graph: {e}", g.name))?;
        check_slot_provenance(&prog)?;

        let w = NetWeights::random(&piped.weight_network(), rng.next_u64());
        let fused = w.fuse();
        let x = input_for_graph(&piped, rng.next_u64());
        let want = reference_forward(&piped, &w, &x);
        let prog = Arc::new(prog);
        let serial =
            ProgramExecutor::new(prog.clone()).run(&Engine::single_threaded(), &fused, &x);
        neuromax::prop_assert!(
            serial.data == want.data,
            "{}: graph program (serial) != reference",
            g.name
        );
        let pooled =
            ProgramExecutor::new(prog).run(&Engine::pooled_forced(pool.clone()), &fused, &x);
        neuromax::prop_assert!(
            pooled.data == want.data,
            "{}: graph program (pooled) != reference",
            g.name
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// End-to-end: graphs the flat path cannot serve
// ---------------------------------------------------------------------

#[test]
fn diamond_graphs_serve_end_to_end_through_the_engine() {
    let g = diamond_graph();
    // the flat layer list reads this as a straight chain — no residual
    // route anywhere — so only the graph path can serve the diamond
    let flat_plan = ForwardPlan::infer(&g.weight_network()).unwrap();
    assert!(
        !flat_plan.routes.iter().any(|r| matches!(r, Routing::Residual(..))),
        "flat inference cannot see the diamond's residual rejoin"
    );

    let seed = 0xD1A;
    let eopt = EngineOptions { num_threads: 2, par_min_work: 1 };
    let mut eng = InferenceEngine::for_graph(&g, seed, eopt, None).expect("engine for graph");
    let piped = run_pipeline(&g, &default_pipeline()).unwrap();
    let w = NetWeights::random(&piped.weight_network(), seed);

    let x = eng.input(7);
    let want = reference_forward(&piped, &w, &x);
    let inf = eng.infer(&x).expect("diamond inference");
    assert_eq!(inf.logits, want.data, "served logits != IR reference");

    let xs: Vec<_> = (0..3).map(|i| eng.input(100 + i)).collect();
    let infs = eng.infer_batch(&xs).expect("diamond batch");
    for (i, (inf, x)) in infs.iter().zip(&xs).enumerate() {
        let want = reference_forward(&piped, &w, x);
        assert_eq!(inf.logits, want.data, "batch element {i} diverged");
    }
}
