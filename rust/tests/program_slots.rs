//! Buffer-slot reuse safety + program bit-exactness over **random
//! zoo-like graphs**.
//!
//! The program compiler recycles arena slots from a liveness analysis
//! (generalizing `drive`'s `last_use` freeing into a static
//! assignment). The property that must hold for any routable graph: no
//! step may read a slot after the slot-reuse assignment has recycled it
//! for another producer. We check it two ways:
//!
//! 1. **Provenance replay**: walk the compiled steps, tracking which
//!    layer's data each slot currently holds; every read's recorded
//!    `src_layer` must match the slot's current owner, and a step's
//!    staged/output slots must not alias anything it reads.
//! 2. **Numerics**: the program executor must be bit-identical to the
//!    reference forward (`dataflow::exec` numerics) and the legacy
//!    engine driver on the same graph — a recycled-too-early slot
//!    cannot hide from an exact output comparison.
//!
//! The graph generator and the provenance replay live in
//! `common::graphgen`, shared with the typed-IR pass suite
//! (`ir_passes.rs`). Generated graphs exercise every routing form the
//! zoo uses — plain chains (conv/depthwise/pool), SqueezeNet-style fire
//! modules (fan-out + channel concat), ResNet-style projection pairs
//! (residual merge feeding padded convs), flatten-Fc heads — plus
//! orphan layers and post-fc pointwise tails that only the IR pass
//! pipeline cleans up.

mod common;

use std::sync::Arc;

use common::graphgen::{check_slot_provenance, random_net};
use neuromax::dataflow::forward::{forward_engine_planned, forward_ref_planned, ForwardPlan};
use neuromax::dataflow::program::{run_batch_lockstep, ModelProgram, ProgramExecutor};
use neuromax::dataflow::workers::WorkerPool;
use neuromax::dataflow::{Engine, Split};
use neuromax::models::layer::{LayerDesc, Network};
use neuromax::models::runner::{random_input_for, NetWeights};
use neuromax::models::workload;
use neuromax::tensor::Tensor3;
use neuromax::util::proptest::check;

#[test]
fn random_graphs_recycle_slots_safely_and_stay_bit_exact() {
    let pool = WorkerPool::new(3);
    check("program-slot-reuse", 25, |rng| {
        let tag = rng.next_u64() & 0xFFFF;
        let net = random_net(rng, tag);
        let plan = ForwardPlan::infer(&net)
            .map_err(|e| format!("{}: plan failed: {e}", net.name))?;
        let prog = ModelProgram::from_plan(&net, &plan);
        check_slot_provenance(&prog)?;
        neuromax::prop_assert!(
            prog.slot_sizes.len() <= net.layers.len() + 1,
            "{}: slot reuse assigned {} slots for {} layers",
            net.name,
            prog.slot_sizes.len(),
            net.layers.len()
        );

        let w = NetWeights::random(&net, rng.next_u64());
        let fused = w.fuse();
        let x = random_input_for(&net, rng.next_u64());
        let want = forward_ref_planned(&net, &plan, &w, &x);
        let legacy = forward_engine_planned(
            &Engine::with_threads_forced(2),
            &net,
            &plan,
            &fused,
            &x,
        );
        neuromax::prop_assert!(
            legacy == want,
            "{}: legacy engine driver != reference",
            net.name
        );

        let mut ex = ProgramExecutor::new(Arc::new(prog));
        let serial = ex.run(&Engine::single_threaded(), &fused, &x);
        neuromax::prop_assert!(
            serial == want,
            "{}: program executor (serial) != reference",
            net.name
        );
        // pooled engine, forced row-parallelism, arena reused from the
        // serial run: numerics and liveness must both hold
        let grows = ex.arena_grow_events();
        let pooled = ex.run(&Engine::pooled_forced(pool.clone()), &fused, &x);
        neuromax::prop_assert!(
            pooled == want,
            "{}: program executor (pooled) != reference",
            net.name
        );
        neuromax::prop_assert!(
            ex.arena_grow_events() == grows,
            "{}: warmed arena grew on re-run",
            net.name
        );
        Ok(())
    });
}

#[test]
fn lockstep_batches_match_per_element_execution_on_random_graphs() {
    // the nested batch×row executor must be bit-identical to running
    // each element through the per-element executor, for any routable
    // graph shape and any batch size — and its per-step plans must
    // cover every output row of every element exactly once (checked
    // indirectly: a gap leaves stale psums, an overlap double-writes;
    // both break the exact comparison)
    let pool = WorkerPool::new(4);
    check("lockstep-batch", 12, |rng| {
        let tag = rng.next_u64() & 0xFFFF;
        let net = random_net(rng, tag);
        let plan = ForwardPlan::infer(&net)
            .map_err(|e| format!("{}: plan failed: {e}", net.name))?;
        let prog = Arc::new(ModelProgram::from_plan(&net, &plan));
        let w = NetWeights::random(&net, rng.next_u64());
        let fused = w.fuse();
        let b = 2 + rng.below(4) as usize;
        let xs: Vec<Tensor3> =
            (0..b as u64).map(|i| random_input_for(&net, rng.next_u64() ^ i)).collect();
        let eng1 = Engine::single_threaded();
        let mut exr = ProgramExecutor::new(prog.clone());
        let want: Vec<Tensor3> = xs.iter().map(|x| exr.run(&eng1, &fused, x)).collect();
        // forced pooled engine: every step with >1 row splits, so the
        // job really interleaves (element × chunk) pairs
        let engp = Engine::pooled_forced(pool.clone());
        let pplan = prog.plans_for(engp.num_threads(), true, true);
        neuromax::prop_assert!(
            pplan.steps.iter().any(|p| p.split == Split::Rows)
                || prog.steps.iter().all(|s| s.plan_rows_axis() <= 1),
            "{}: forced plan should row-split something",
            net.name
        );
        let mut execs: Vec<ProgramExecutor> =
            (0..b).map(|_| ProgramExecutor::new(prog.clone())).collect();
        let mut refs: Vec<&mut ProgramExecutor> = execs.iter_mut().collect();
        let xrefs: Vec<&Tensor3> = xs.iter().collect();
        let mut outs = vec![Vec::new(); b];
        let dims = run_batch_lockstep(&engp, &fused, &pplan, &mut refs, &xrefs, &mut outs);
        for (e, (got, want)) in outs.iter().zip(&want).enumerate() {
            neuromax::prop_assert!(
                dims == (want.h, want.w, want.c),
                "{}: lockstep dims {:?}",
                net.name,
                dims
            );
            neuromax::prop_assert!(
                got == &want.data,
                "{}: lockstep element {e}/{b} diverged",
                net.name
            );
        }
        Ok(())
    });
}

#[test]
fn zoo_programs_pass_the_provenance_replay() {
    for name in workload::ZOO_NAMES {
        for net in [
            workload::by_name(name).unwrap(),
            workload::test_profile(name).unwrap(),
        ] {
            let prog = ModelProgram::compile(&net)
                .unwrap_or_else(|e| panic!("{}: {e}", net.name));
            check_slot_provenance(&prog).unwrap_or_else(|e| panic!("{}: {e}", net.name));
        }
    }
}

#[test]
fn deep_chains_ping_pong_a_constant_slot_count() {
    // a 40-layer shape-preserving chain must not grow slots linearly —
    // that is the whole point of the liveness assignment
    let mut layers = Vec::new();
    for i in 0..40 {
        layers.push(LayerDesc::conv(&format!("c{i}"), 3, 1, 1, 8, 8, 4, 4));
    }
    let net = Network { name: "deep-chain".into(), layers };
    let prog = ModelProgram::compile(&net).unwrap();
    check_slot_provenance(&prog).unwrap();
    assert!(
        prog.slot_sizes.len() <= 3,
        "deep chain needs a constant number of slots, got {}",
        prog.slot_sizes.len()
    );
}
