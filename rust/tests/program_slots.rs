//! Buffer-slot reuse safety + program bit-exactness over **random
//! zoo-like graphs**.
//!
//! The program compiler recycles arena slots from a liveness analysis
//! (generalizing `drive`'s `last_use` freeing into a static
//! assignment). The property that must hold for any routable graph: no
//! step may read a slot after the slot-reuse assignment has recycled it
//! for another producer. We check it two ways:
//!
//! 1. **Provenance replay**: walk the compiled steps, tracking which
//!    layer's data each slot currently holds; every read's recorded
//!    `src_layer` must match the slot's current owner, and a step's
//!    staged/output slots must not alias anything it reads.
//! 2. **Numerics**: the program executor must be bit-identical to the
//!    reference forward (`dataflow::exec` numerics) and the legacy
//!    engine driver on the same graph — a recycled-too-early slot
//!    cannot hide from an exact output comparison.
//!
//! Graphs are generated to exercise every routing form the zoo uses:
//! plain chains (conv/depthwise/pool), SqueezeNet-style fire modules
//! (fan-out + channel concat), ResNet-style projection pairs (residual
//! merge feeding padded convs), and flatten-Fc heads.

use std::sync::Arc;

use neuromax::dataflow::forward::{forward_engine_planned, forward_ref_planned, ForwardPlan};
use neuromax::dataflow::program::{
    run_batch_lockstep, Input, Merge, ModelProgram, Operand, ProgramExecutor,
};
use neuromax::dataflow::workers::WorkerPool;
use neuromax::dataflow::{Engine, Split};
use neuromax::tensor::Tensor3;
use neuromax::models::layer::{LayerDesc, Network};
use neuromax::models::runner::{random_input_for, NetWeights};
use neuromax::models::workload;
use neuromax::util::prng::SplitMix64;
use neuromax::util::proptest::check;

/// Generate a random routable zoo-like network. Shape-preserving ops
/// keep the bookkeeping exact; fire and residual segments leave their
/// merge pending for the *next* layer (exactly how the plan inference
/// discovers them), so the generator always materializes a join before
/// ending or branching again.
fn random_net(rng: &mut SplitMix64, tag: u64) -> Network {
    let mut h = 6 + rng.below(7) as usize;
    let mut w = 6 + rng.below(5) as usize;
    let mut c = 1 + rng.below(3) as usize;
    let mut layers: Vec<LayerDesc> = Vec::new();
    let mut li = 0usize;
    let name = |li: &mut usize, s: &str| {
        *li += 1;
        format!("{s}{li}")
    };
    // a plain shape-compatible consumer: conv3/conv1/depthwise/pool
    let plain = |rng: &mut SplitMix64,
                 layers: &mut Vec<LayerDesc>,
                 li: &mut usize,
                 h: &mut usize,
                 w: &mut usize,
                 c: &mut usize| {
        match rng.below(4) {
            0 => {
                let co = 1 + rng.below(5) as usize;
                layers.push(LayerDesc::conv(
                    &format!("c3_{li}"), 3, 1, 1, *h, *w, *c, co,
                ));
                *li += 1;
                *c = co;
            }
            1 => {
                let co = 1 + rng.below(5) as usize;
                layers.push(LayerDesc::pointwise(&format!("pw{li}"), *h, *w, *c, co));
                *li += 1;
                *c = co;
            }
            2 => {
                layers.push(LayerDesc::depthwise(&format!("dw{li}"), 1, *h, *w, *c));
                *li += 1;
            }
            _ => {
                if *h >= 4 && *w >= 4 {
                    if rng.bool(0.5) {
                        layers.push(LayerDesc::pool(&format!("mp{li}"), 2, 2, *h, *w, *c));
                    } else {
                        layers.push(LayerDesc::avgpool(&format!("ap{li}"), 2, 2, *h, *w, *c));
                    }
                    *li += 1;
                    *h = (*h - 2) / 2 + 1;
                    *w = (*w - 2) / 2 + 1;
                } else {
                    layers.push(LayerDesc::depthwise(&format!("dw{li}"), 1, *h, *w, *c));
                    *li += 1;
                }
            }
        }
    };
    let segments = 2 + rng.below(3);
    for _ in 0..segments {
        match rng.below(4) {
            // fire module: squeeze → two expand branches → (pending concat)
            0 => {
                let s = 1 + rng.below(3) as usize;
                let c1 = 1 + rng.below(3) as usize;
                let c2 = 1 + rng.below(3) as usize;
                layers.push(LayerDesc::pointwise(&name(&mut li, "sq"), h, w, c, s));
                layers.push(LayerDesc::pointwise(&name(&mut li, "e1_"), h, w, s, c1));
                layers.push(LayerDesc::conv(&name(&mut li, "e3_"), 3, 1, 1, h, w, s, c2));
                c = c1 + c2;
                // materialize the concat in a plain consumer
                plain(rng, &mut layers, &mut li, &mut h, &mut w, &mut c);
            }
            // residual pair: A (3×3, channel change) beside B (1×1
            // projection re-reading A's input) → (pending merge)
            1 => {
                let co = c + 1 + rng.below(3) as usize; // co != c: B re-reads
                layers.push(LayerDesc::conv(&name(&mut li, "ra"), 3, 1, 1, h, w, c, co));
                layers.push(LayerDesc::pointwise(&name(&mut li, "rb"), h, w, c, co));
                c = co;
                // materialize the merge in a plain consumer
                plain(rng, &mut layers, &mut li, &mut h, &mut w, &mut c);
            }
            _ => plain(rng, &mut layers, &mut li, &mut h, &mut w, &mut c),
        }
    }
    if rng.bool(0.6) {
        layers.push(LayerDesc::fc("fc", h * w * c, 1 + rng.below(8) as usize));
    }
    Network { name: format!("randgraph-{tag}"), layers }
}

/// Replay a compiled program's slot traffic, asserting every read sees
/// the producer it was compiled against and no step aliases its own
/// reads.
fn check_slot_provenance(prog: &ModelProgram) -> Result<(), String> {
    let mut owner: Vec<Option<usize>> = vec![None; prog.slot_sizes.len()];
    let read_ok = |owner: &[Option<usize>], op: &Operand, step: usize| -> Result<(), String> {
        if let Some(s) = op.slot {
            if owner[s] != Some(op.src_layer) {
                return Err(format!(
                    "step {step} reads slot {s} expecting layer {}, but it holds {:?} \
                     (recycled before last use)",
                    op.src_layer, owner[s]
                ));
            }
        }
        Ok(())
    };
    for (i, step) in prog.steps.iter().enumerate() {
        let mut reads: Vec<usize> = Vec::new();
        let mut see = |op: &Operand| {
            if let Some(s) = op.slot {
                reads.push(s);
            }
        };
        match &step.input {
            Input::Direct(op) => {
                read_ok(&owner, op, i)?;
                see(op);
            }
            Input::Staged(sp) => {
                match &sp.merge {
                    Merge::Copy(a) => {
                        read_ok(&owner, a, i)?;
                        see(a);
                    }
                    Merge::Concat(a, b) | Merge::Residual(a, b) => {
                        read_ok(&owner, a, i)?;
                        read_ok(&owner, b, i)?;
                        see(a);
                        see(b);
                    }
                }
                if reads.contains(&sp.slot) {
                    return Err(format!("step {i}: stage slot {} aliases a read", sp.slot));
                }
                if sp.slot == step.out_slot {
                    return Err(format!("step {i}: stage slot == out slot {}", sp.slot));
                }
                // the staged buffer is transient: dead after this step
                owner[sp.slot] = None;
            }
        }
        if reads.contains(&step.out_slot) {
            return Err(format!("step {i}: out slot {} aliases a read", step.out_slot));
        }
        owner[step.out_slot] = Some(step.layer);
    }
    Ok(())
}

#[test]
fn random_graphs_recycle_slots_safely_and_stay_bit_exact() {
    let pool = WorkerPool::new(3);
    check("program-slot-reuse", 25, |rng| {
        let tag = rng.next_u64() & 0xFFFF;
        let net = random_net(rng, tag);
        let plan = ForwardPlan::infer(&net)
            .map_err(|e| format!("{}: plan failed: {e}", net.name))?;
        let prog = ModelProgram::from_plan(&net, &plan);
        check_slot_provenance(&prog)?;
        neuromax::prop_assert!(
            prog.slot_sizes.len() <= net.layers.len() + 1,
            "{}: slot reuse assigned {} slots for {} layers",
            net.name,
            prog.slot_sizes.len(),
            net.layers.len()
        );

        let w = NetWeights::random(&net, rng.next_u64());
        let fused = w.fuse();
        let x = random_input_for(&net, rng.next_u64());
        let want = forward_ref_planned(&net, &plan, &w, &x);
        let legacy = forward_engine_planned(
            &Engine::with_threads_forced(2),
            &net,
            &plan,
            &fused,
            &x,
        );
        neuromax::prop_assert!(
            legacy == want,
            "{}: legacy engine driver != reference",
            net.name
        );

        let mut ex = ProgramExecutor::new(Arc::new(prog));
        let serial = ex.run(&Engine::single_threaded(), &fused, &x);
        neuromax::prop_assert!(
            serial == want,
            "{}: program executor (serial) != reference",
            net.name
        );
        // pooled engine, forced row-parallelism, arena reused from the
        // serial run: numerics and liveness must both hold
        let grows = ex.arena_grow_events();
        let pooled = ex.run(&Engine::pooled_forced(pool.clone()), &fused, &x);
        neuromax::prop_assert!(
            pooled == want,
            "{}: program executor (pooled) != reference",
            net.name
        );
        neuromax::prop_assert!(
            ex.arena_grow_events() == grows,
            "{}: warmed arena grew on re-run",
            net.name
        );
        Ok(())
    });
}

#[test]
fn lockstep_batches_match_per_element_execution_on_random_graphs() {
    // the nested batch×row executor must be bit-identical to running
    // each element through the per-element executor, for any routable
    // graph shape and any batch size — and its per-step plans must
    // cover every output row of every element exactly once (checked
    // indirectly: a gap leaves stale psums, an overlap double-writes;
    // both break the exact comparison)
    let pool = WorkerPool::new(4);
    check("lockstep-batch", 12, |rng| {
        let tag = rng.next_u64() & 0xFFFF;
        let net = random_net(rng, tag);
        let plan = ForwardPlan::infer(&net)
            .map_err(|e| format!("{}: plan failed: {e}", net.name))?;
        let prog = Arc::new(ModelProgram::from_plan(&net, &plan));
        let w = NetWeights::random(&net, rng.next_u64());
        let fused = w.fuse();
        let b = 2 + rng.below(4) as usize;
        let xs: Vec<Tensor3> =
            (0..b as u64).map(|i| random_input_for(&net, rng.next_u64() ^ i)).collect();
        let eng1 = Engine::single_threaded();
        let mut exr = ProgramExecutor::new(prog.clone());
        let want: Vec<Tensor3> = xs.iter().map(|x| exr.run(&eng1, &fused, x)).collect();
        // forced pooled engine: every step with >1 row splits, so the
        // job really interleaves (element × chunk) pairs
        let engp = Engine::pooled_forced(pool.clone());
        let pplan = prog.plans_for(engp.num_threads(), true, true);
        neuromax::prop_assert!(
            pplan.steps.iter().any(|p| p.split == Split::Rows)
                || prog.steps.iter().all(|s| s.plan_rows_axis() <= 1),
            "{}: forced plan should row-split something",
            net.name
        );
        let mut execs: Vec<ProgramExecutor> =
            (0..b).map(|_| ProgramExecutor::new(prog.clone())).collect();
        let mut refs: Vec<&mut ProgramExecutor> = execs.iter_mut().collect();
        let xrefs: Vec<&Tensor3> = xs.iter().collect();
        let mut outs = vec![Vec::new(); b];
        let dims = run_batch_lockstep(&engp, &fused, &pplan, &mut refs, &xrefs, &mut outs);
        for (e, (got, want)) in outs.iter().zip(&want).enumerate() {
            neuromax::prop_assert!(
                dims == (want.h, want.w, want.c),
                "{}: lockstep dims {:?}",
                net.name,
                dims
            );
            neuromax::prop_assert!(
                got == &want.data,
                "{}: lockstep element {e}/{b} diverged",
                net.name
            );
        }
        Ok(())
    });
}

#[test]
fn zoo_programs_pass_the_provenance_replay() {
    for name in workload::ZOO_NAMES {
        for net in [
            workload::by_name(name).unwrap(),
            workload::test_profile(name).unwrap(),
        ] {
            let prog = ModelProgram::compile(&net)
                .unwrap_or_else(|e| panic!("{}: {e}", net.name));
            check_slot_provenance(&prog).unwrap_or_else(|e| panic!("{}: {e}", net.name));
        }
    }
}

#[test]
fn deep_chains_ping_pong_a_constant_slot_count() {
    // a 40-layer shape-preserving chain must not grow slots linearly —
    // that is the whole point of the liveness assignment
    let mut layers = Vec::new();
    for i in 0..40 {
        layers.push(LayerDesc::conv(&format!("c{i}"), 3, 1, 1, 8, 8, 4, 4));
    }
    let net = Network { name: "deep-chain".into(), layers };
    let prog = ModelProgram::compile(&net).unwrap();
    check_slot_provenance(&prog).unwrap();
    assert!(
        prog.slot_sizes.len() <= 3,
        "deep chain needs a constant number of slots, got {}",
        prog.slot_sizes.len()
    );
}
