//! Online cost-recalibration integration tests. These live in their own
//! test binary because they bump the process-global cost generation
//! (`recalibrate_cost_override`), which re-plans every cached program —
//! numerically safe (plans never change results, only splits), but it
//! would churn the plan-cache pins the lib tests assert on in their
//! shared process.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use neuromax::coordinator::replicate::{RecalPolicy, Recalibrator};
use neuromax::dataflow::{
    cached_program, cost_generation, recalibrate_cost_override, CostOverride, CostSamples,
    SwCost,
};
use neuromax::models::workload;

/// The cost store is process-global: serialize the tests that flip it.
fn cost_guard() -> MutexGuard<'static, ()> {
    static GUARD: OnceLock<Mutex<()>> = OnceLock::new();
    GUARD.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn recalibrated_costs_recompile_cached_plans_and_flip_gemm_routing() {
    let _g = cost_guard();
    let net = workload::by_name("vgg16-test").unwrap();
    let prog = cached_program(&net).unwrap();

    // steady generation: the memo must answer with the same Arc — the
    // no-churn half of the contract
    let before = prog.plans_for(4, true, false);
    let again = prog.plans_for(4, true, false);
    assert!(Arc::ptr_eq(&before, &again), "stable costs must not churn the plan cache");

    // measured GEMM ~50 ns/MAC (two orders over the defaults): the
    // planner must route every step back onto the row kernels
    let g0 = cost_generation();
    let g1 = recalibrate_cost_override(CostOverride {
        ns_per_mac: Some(0.05),
        ns_per_mac_gemm_scalar: Some(49.0),
        ns_per_mac_gemm_avx2: Some(49.0),
        ns_per_mac_gemm_neon: Some(49.0),
        ..Default::default()
    });
    assert!(g1 > g0, "an install must bump the cost generation");
    let rows_only = prog.plans_for(4, true, false);
    assert!(!Arc::ptr_eq(&before, &rows_only), "a generation bump must recompile");
    let gemm_after = rows_only.steps.iter().filter(|s| s.gemm.is_some()).count();
    assert_eq!(gemm_after, 0, "49 ns/MAC GEMM must never pay");

    // flipped skew — rows 45 ns/MAC, GEMM nearly free: conv steps must
    // route onto the GEMM micro-kernel instead
    let g2 = recalibrate_cost_override(CostOverride {
        ns_per_mac: Some(45.0),
        ns_per_mac_gemm_scalar: Some(0.05),
        ns_per_mac_gemm_avx2: Some(0.05),
        ns_per_mac_gemm_neon: Some(0.05),
        gemm_pack_ns: Some(0.01),
    });
    assert!(g2 > g1);
    let gemm_heavy = prog.plans_for(4, true, false);
    assert!(!Arc::ptr_eq(&rows_only, &gemm_heavy));
    let gemm_count = gemm_heavy.steps.iter().filter(|s| s.gemm.is_some()).count();
    assert!(gemm_count > 0, "45 ns/MAC rows must push convolutions onto GEMM");
}

#[test]
fn the_recalibrator_installs_only_on_confidently_skewed_samples() {
    let _g = cost_guard();
    let base = SwCost::for_substrate(true);
    let mut r = Recalibrator::new(RecalPolicy::default(), base.ns_per_mac, base.ns_per_mac_gemm());
    let net = workload::by_name("tinycnn").unwrap();
    let prog = cached_program(&net).unwrap();

    // accurate samples (measured == applied model): the dead band keeps
    // the recalibrator silent, so the generation — and every cached plan
    // Arc — is untouched
    let macs = 200_000_000u64; // well past the confidence floor
    let accurate = CostSamples {
        rows_busy_ns: (macs as f64 * base.ns_per_mac) as u64,
        rows_macs: macs,
        gemm_busy_ns: (macs as f64 * base.ns_per_mac_gemm()) as u64,
        gemm_macs: macs,
    };
    let g0 = cost_generation();
    let pinned = prog.plans_for(2, true, false);
    for _ in 0..20 {
        let up = r.observe(&accurate);
        assert!(up.is_empty(), "accurate samples must never trigger an install");
    }
    assert_eq!(cost_generation(), g0, "no install, no generation bump");
    assert!(
        Arc::ptr_eq(&pinned, &prog.plans_for(2, true, false)),
        "accurate costs must never churn the plan cache"
    );

    // 3x-slow rows with the same confidence: one EWMA step lands far
    // outside the dead band and the update installs, exactly the way
    // the pool controller applies it
    let skewed = CostSamples {
        rows_busy_ns: (macs as f64 * base.ns_per_mac * 3.0) as u64,
        rows_macs: macs,
        gemm_busy_ns: 0,
        gemm_macs: 0,
    };
    let up = r.observe(&skewed);
    let rows = up.rows_ns_per_mac.expect("a 3x skew must install");
    assert!(
        rows > base.ns_per_mac,
        "installed rows cost must move toward the measurement: {rows}"
    );
    let g1 = recalibrate_cost_override(CostOverride {
        ns_per_mac: Some(rows),
        ..Default::default()
    });
    assert!(g1 > g0, "the install must be visible to every plan cache");
    assert!(
        !Arc::ptr_eq(&pinned, &prog.plans_for(2, true, false)),
        "skewed install must recompile the cached plans"
    );
}
