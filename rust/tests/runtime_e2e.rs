//! Runtime + coordinator integration: artifacts load and execute via
//! PJRT, the executables agree bit-for-bit with the simulator, and the
//! full serving pipeline works over them. Skips (loudly) when artifacts
//! haven't been built.

mod common;

use neuromax::coordinator::pipeline::{Backend, InferenceEngine};
use neuromax::runtime::{exec, verify, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = common::artifacts_dir()?;
    Some(Runtime::new(dir).expect("runtime init"))
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in [
        "logconv3x3_s1", "logconv3x3_s2", "logconv1x1", "logdw3x3",
        "postprocess", "tinycnn",
    ] {
        assert!(rt.manifest().get(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn every_artifact_compiles() {
    let Some(mut rt) = runtime() else { return };
    let names: Vec<String> = rt.manifest().artifacts.keys().cloned().collect();
    for name in names {
        rt.load(&name).unwrap_or_else(|e| panic!("compiling {name}: {e:#}"));
    }
}

#[test]
fn conv3x3_hlo_matches_sim_and_core() {
    let Some(mut rt) = runtime() else { return };
    let rep = verify::verify_conv3x3(&mut rt, 99).unwrap();
    assert!(rep.ok(), "{} mismatches", rep.mismatches);
    assert_eq!(rep.elements_compared, 16 * 16 * 16);
}

#[test]
fn tinycnn_hlo_matches_sim_over_many_cases() {
    let Some(mut rt) = runtime() else { return };
    let rep = verify::verify_tinycnn(&mut rt, 6, 12345).unwrap();
    assert!(rep.ok(), "{} mismatches", rep.mismatches);
}

#[test]
fn postprocess_artifact_matches_requant_table() {
    let Some(mut rt) = runtime() else { return };
    use neuromax::lns::tables::requant_act;
    use neuromax::tensor::Tensor3;
    use neuromax::util::prng::SplitMix64;
    let mut rng = SplitMix64::new(3);
    let mut psums = Tensor3::new(16, 16, 16);
    for v in psums.data.iter_mut() {
        *v = rng.range_i32(-5_000_000, 50_000_000);
    }
    let out = exec::postprocess(&mut rt, &psums).unwrap();
    for (p, c) in psums.data.iter().zip(&out.data) {
        assert_eq!(requant_act(*p), *c, "psum {p}");
    }
}

#[test]
fn fused_artifact_equals_conv_plus_requant() {
    let Some(mut rt) = runtime() else { return };
    use neuromax::dataflow::exec as fexec;
    use neuromax::lns::logquant::ZERO_CODE;
    use neuromax::tensor::{Tensor3, Tensor4};
    use neuromax::util::prng::SplitMix64;
    let mut rng = SplitMix64::new(21);
    let mut a = Tensor3::new(18, 18, 8);
    for v in a.data.iter_mut() {
        *v = if rng.bool(0.1) { ZERO_CODE } else { rng.range_i32(-12, 8) };
    }
    let mut wc = Tensor4::new(16, 3, 3, 8);
    let mut ws = Tensor4::new(16, 3, 3, 8);
    for v in wc.data.iter_mut() {
        *v = rng.range_i32(-12, 8);
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }
    let outs = rt
        .run_i32(
            "logconv3x3_fused",
            &[a.data.clone(), wc.data.clone(), ws.data.clone()],
        )
        .unwrap();
    let want = fexec::requant(&fexec::conv2d(&a, &wc, &ws, 1));
    assert_eq!(outs[0], want.data, "fused HLO != conv+requant composition");
}

#[test]
fn missing_hlo_file_fails_loudly() {
    let Some(dir) = common::artifacts_dir() else { return };
    // synthesize a manifest pointing at a nonexistent file
    let tmp = std::env::temp_dir().join("neuromax_bad_manifest");
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::write(
        tmp.join("manifest.txt"),
        "artifact ghost missing.hlo.txt\nin x s32 4\nout y s32 4\nend\n",
    )
    .unwrap();
    let mut rt = Runtime::new(&tmp).expect("manifest parses");
    let err = match rt.load("ghost") {
        Err(e) => e,
        Ok(_) => panic!("loading a missing HLO file should fail"),
    };
    assert!(format!("{err:#}").contains("missing.hlo.txt"), "{err:#}");
    let _ = dir;
}

#[test]
fn corrupt_hlo_text_fails_loudly() {
    if common::artifacts_dir().is_none() {
        return;
    }
    let tmp = std::env::temp_dir().join("neuromax_corrupt_hlo");
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::write(tmp.join("bad.hlo.txt"), "this is not hlo").unwrap();
    std::fs::write(
        tmp.join("manifest.txt"),
        "artifact bad bad.hlo.txt\nin x s32 4\nout y s32 4\nend\n",
    )
    .unwrap();
    let mut rt = Runtime::new(&tmp).unwrap();
    assert!(rt.load("bad").is_err());
}

#[test]
fn bad_input_shape_is_rejected() {
    let Some(mut rt) = runtime() else { return };
    let r = rt.run_i32("postprocess", &[vec![1, 2, 3]]); // wrong size
    assert!(r.is_err());
    let r = rt.run_i32("postprocess", &[]); // wrong arity
    assert!(r.is_err());
}

#[test]
fn hlo_engine_and_sim_engine_agree_end_to_end() {
    if common::artifacts_dir().is_none() {
        return;
    }
    let mut hlo = InferenceEngine::new(Backend::Hlo, 7).expect("hlo engine");
    let mut sim = InferenceEngine::new(Backend::Sim, 7).expect("sim engine");
    for seed in 0..6 {
        let input = InferenceEngine::input_for_seed(seed);
        let a = hlo.infer(&input).unwrap();
        let b = sim.infer(&input).unwrap();
        assert_eq!(a.logits, b.logits, "seed {seed}");
        assert_eq!(a.class, b.class);
    }
}
