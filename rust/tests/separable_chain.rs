//! Full separable-block chain (the MobileNet motif of §5.2) through BOTH
//! execution paths — fast functional executor vs hardware-faithful core —
//! with requant and pooling between layers. Two independent
//! implementations of the whole chain must agree bit-for-bit.

mod common;

use neuromax::arch::ConvCore;
use neuromax::dataflow::{exec, pool};
use neuromax::lns::logquant::ZERO_CODE;
use neuromax::tensor::{Tensor3, Tensor4};
use neuromax::util::prng::SplitMix64;

fn codes3(rng: &mut SplitMix64, h: usize, w: usize, c: usize) -> Tensor3 {
    let mut t = Tensor3::new(h, w, c);
    for v in t.data.iter_mut() {
        *v = if rng.bool(0.08) { ZERO_CODE } else { rng.range_i32(-10, 6) };
    }
    t
}

fn weights(rng: &mut SplitMix64, k: usize, kh: usize, kw: usize, c: usize) -> (Tensor4, Tensor4) {
    let mut wc = Tensor4::new(k, kh, kw, c);
    let mut ws = Tensor4::new(k, kh, kw, c);
    for v in wc.data.iter_mut() {
        *v = if rng.bool(0.08) { ZERO_CODE } else { rng.range_i32(-10, 5) };
    }
    for v in ws.data.iter_mut() {
        *v = rng.sign();
    }
    (wc, ws)
}

/// conv3×3 s2 → requant → dw3×3 → requant → pw 1×1 → requant → maxpool 2.
#[test]
fn separable_block_functional_vs_faithful() {
    let mut rng = SplitMix64::new(2026);
    let a = codes3(&mut rng, 19, 19, 3);
    let (w1c, w1s) = weights(&mut rng, 8, 3, 3, 3); // conv s2: 19→9
    let (wdc, wds) = weights(&mut rng, 8, 3, 3, 1); // dw: 9→7
    let (wpc, wps) = weights(&mut rng, 12, 1, 1, 8); // pw: 7→7, C 8→12

    // --- functional path -------------------------------------------------
    let f1 = exec::requant(&exec::conv2d(&a, &w1c, &w1s, 2));
    let f2 = exec::requant(&exec::depthwise(&f1, &wdc, &wds, 1));
    let f3 = exec::requant(&exec::pointwise(&f2, &wpc, &wps, 1));
    let f4 = pool::maxpool(&f3, 2, 2);

    // --- hardware-faithful path ------------------------------------------
    let mut core = ConvCore::default();
    let (p1, s1) = core.conv3x3(&a, &w1c, &w1s, 2);
    let h1 = p1.map(neuromax::lns::requant_act);
    let (p2, s2) = core.depthwise(&h1, &wdc, &wds, 1);
    let h2 = p2.map(neuromax::lns::requant_act);
    let (p3, s3) = core.conv1x1(&h2, &wpc, &wps);
    let h3 = p3.map(neuromax::lns::requant_act);
    let h4 = pool::maxpool(&h3, 2, 2);

    assert_eq!(f1, h1, "conv stage diverged");
    assert_eq!(f2, h2, "depthwise stage diverged");
    assert_eq!(f3, h3, "pointwise stage diverged");
    assert_eq!(f4, h4, "pooled outputs diverged");

    // schedule sanity: every stage billed cycles and stayed within budget
    for (name, st) in [("conv", &s1), ("dw", &s2), ("pw", &s3)] {
        assert!(st.cycles > 0, "{name}: no cycles");
        assert!(
            st.utilization_used() <= 1.0 + 1e-9,
            "{name}: utilization {}",
            st.utilization_used()
        );
        assert!(st.cycles >= st.useful_macs / 324, "{name}: beat roofline");
    }
}

/// The same property over random block shapes.
#[test]
fn separable_block_property() {
    neuromax::util::proptest::check("separable-chain", 10, |rng| {
        let hw = 9 + 2 * rng.below(5) as usize; // odd sizes 9..17
        let cin = 1 + rng.below(4) as usize;
        let cmid = 2 + rng.below(8) as usize;
        let cout = 2 + rng.below(12) as usize;
        let a = codes3(rng, hw, hw, cin);
        let (w1c, w1s) = weights(rng, cmid, 3, 3, cin);
        let (wdc, wds) = weights(rng, cmid, 3, 3, 1);
        let (wpc, wps) = weights(rng, cout, 1, 1, cmid);

        let f1 = exec::requant(&exec::conv2d(&a, &w1c, &w1s, 2));
        let f2 = exec::requant(&exec::depthwise(&f1, &wdc, &wds, 1));
        let f3 = exec::requant(&exec::pointwise(&f2, &wpc, &wps, 1));

        let mut core = ConvCore::default();
        let (p1, _) = core.conv3x3(&a, &w1c, &w1s, 2);
        let h1 = p1.map(neuromax::lns::requant_act);
        let (p2, _) = core.depthwise(&h1, &wdc, &wds, 1);
        let h2 = p2.map(neuromax::lns::requant_act);
        let (p3, _) = core.conv1x1(&h2, &wpc, &wps);
        let h3 = p3.map(neuromax::lns::requant_act);

        neuromax::prop_assert!(f3 == h3, "chain diverged at hw={hw} cin={cin} cmid={cmid} cout={cout}");
        neuromax::prop_assert!(f1 == h1 && f2 == h2, "early stage diverged");
        Ok(())
    });
}
