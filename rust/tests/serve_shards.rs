//! Sharded-serving behavior: model-affinity stickiness, spill routing,
//! bounded admission (`BUSY`, never a hang), graceful drain of in-flight
//! batches, and per-model `STATS` accounting against a scripted traffic
//! trace. Pure routing math is unit-tested in `coordinator::shard`; this
//! file drives the real TCP server.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use neuromax::coordinator::batcher::BatchPolicy;
use neuromax::coordinator::pipeline::Backend;
use neuromax::coordinator::server::{Client, Reply, Server};
use neuromax::coordinator::shard::{Admission, JobKind, Pending, PoolOptions, ShardPool};
use neuromax::coordinator::replicate::ReplicationPolicy;
use neuromax::dataflow::engine::EngineOptions;

fn one_worker() -> EngineOptions {
    EngineOptions { num_threads: 1, ..Default::default() }
}

/// Serve until every client thread finished (bounded by `hard` seconds).
fn serve_clients<T>(srv: &mut Server, clients: &[thread::JoinHandle<T>], hard: u64) {
    srv.serve_while(Duration::from_secs(hard), || {
        clients.iter().all(|c| c.is_finished())
    })
    .unwrap();
}

#[test]
fn single_model_traffic_sticks_to_one_shard() {
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        one_worker(),
        4,
    )
    .unwrap();
    let addr = srv.addr;
    let client = thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        // closed loop: each reply lands before the next request, so the
        // home queue is never deep enough to trigger a spill
        for seed in 0..8 {
            let (class, _) = c.infer(seed).unwrap();
            assert!(class < 10);
        }
    });
    serve_clients(&mut srv, std::slice::from_ref(&client), 60);
    client.join().unwrap();
    let busy_shards = srv
        .metrics
        .shards
        .iter()
        .filter(|s| s.requests.load(Ordering::Relaxed) > 0)
        .count();
    assert_eq!(busy_shards, 1, "one model under light load must stay on its home shard");
    assert_eq!(srv.metrics.spills.load(Ordering::Relaxed), 0);
    srv.shutdown();
}

#[test]
fn full_queue_answers_busy_immediately_instead_of_hanging() {
    // queue_cap=1 and a long batching deadline: the first request parks
    // in the only queue slot for ~1.5s, so a second request must be
    // refused with BUSY right away (not queued, not hung).
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(1500),
            queue_cap: 1,
        },
        one_worker(),
        1,
    )
    .unwrap();
    let addr = srv.addr;
    let metrics = srv.metrics.clone();
    let a = thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.request(None, 1).unwrap()
    });
    let b = {
        let metrics = metrics.clone();
        thread::spawn(move || {
            // wait until A's request is admitted, then hit the full queue
            while metrics.requests.load(Ordering::Relaxed) < 1 {
                thread::sleep(Duration::from_millis(5));
            }
            thread::sleep(Duration::from_millis(100));
            let mut c = Client::connect(addr).unwrap();
            let t0 = Instant::now();
            let r = c.request(None, 2).unwrap();
            (r, t0.elapsed())
        })
    };
    srv.serve_while(Duration::from_secs(60), || a.is_finished() && b.is_finished())
        .unwrap();
    let ra = a.join().unwrap();
    let (rb, waited) = b.join().unwrap();
    assert!(
        matches!(ra, Reply::Ok { .. }),
        "the queued request must still be answered: {ra:?}"
    );
    assert!(matches!(rb, Reply::Busy(_)), "expected BUSY, got {rb:?}");
    assert!(
        waited < Duration::from_millis(1000),
        "BUSY must be immediate, took {waited:?}"
    );
    assert!(metrics.dropped_queue_full.load(Ordering::Relaxed) >= 1);
    srv.shutdown();
}

#[test]
fn spilled_request_lands_on_idle_shard_and_is_counted() {
    // Deterministic end-to-end spill via the queue-full fallback: with 2
    // shards and queue_cap=1, request A parks in the home shard's only
    // slot (long batching deadline); request B for the same model routes
    // home, finds it full, and must spill to the idle shard — answered
    // OK (not BUSY), with the spill counted.
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(1500),
            queue_cap: 1,
        },
        one_worker(),
        2,
    )
    .unwrap();
    let addr = srv.addr;
    let metrics = srv.metrics.clone();
    let a = thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.request(None, 1).unwrap()
    });
    let b = {
        let metrics = metrics.clone();
        thread::spawn(move || {
            while metrics.requests.load(Ordering::Relaxed) < 1 {
                thread::sleep(Duration::from_millis(5));
            }
            thread::sleep(Duration::from_millis(100));
            let mut c = Client::connect(addr).unwrap();
            c.request(None, 2).unwrap()
        })
    };
    srv.serve_while(Duration::from_secs(60), || a.is_finished() && b.is_finished())
        .unwrap();
    let ra = a.join().unwrap();
    let rb = b.join().unwrap();
    assert!(matches!(ra, Reply::Ok { .. }), "home-shard request failed: {ra:?}");
    assert!(
        matches!(rb, Reply::Ok { .. }),
        "with an idle shard available the request must spill, not bounce: {rb:?}"
    );
    assert_eq!(metrics.spills.load(Ordering::Relaxed), 1, "{}", metrics.summary());
    assert_eq!(metrics.dropped_queue_full.load(Ordering::Relaxed), 0);
    let busy_shards = metrics
        .shards
        .iter()
        .filter(|s| s.requests.load(Ordering::Relaxed) > 0)
        .count();
    assert_eq!(busy_shards, 2, "the spilled job must execute on the other shard");
    srv.shutdown();
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    // a long max_wait parks every request in the shard queues; shutdown
    // must release and execute them (drain), not strand the clients
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_secs(10),
            queue_cap: 64,
        },
        one_worker(),
        2,
    )
    .unwrap();
    let addr = srv.addr;
    let metrics = srv.metrics.clone();
    let clients: Vec<_> = (0..6)
        .map(|i| {
            thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let model = if i % 2 == 0 { "tinycnn" } else { "alexnet-test" };
                c.infer_model(model, i as u64).unwrap()
            })
        })
        .collect();
    // accept until all six requests are queued, then shut down mid-wait
    let deadline = Instant::now() + Duration::from_secs(30);
    while metrics.requests.load(Ordering::Relaxed) < 6 && Instant::now() < deadline {
        srv.serve_until(Some(Instant::now() + Duration::from_millis(20))).unwrap();
    }
    assert_eq!(metrics.requests.load(Ordering::Relaxed), 6, "requests never arrived");
    thread::sleep(Duration::from_millis(100));
    let t0 = Instant::now();
    srv.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "drain must not wait out the 10s batch deadline"
    );
    for c in clients {
        let (_class, _us) = c.join().unwrap();
    }
    assert_eq!(
        metrics.responses.load(Ordering::Relaxed),
        6,
        "every in-flight request must be answered during drain: {}",
        metrics.summary()
    );
}

#[test]
fn pool_rejects_new_work_while_draining() {
    let pool = ShardPool::start(
        "tinycnn",
        Backend::Sim,
        BatchPolicy::default(),
        one_worker(),
        2,
    )
    .unwrap();
    assert_eq!(pool.num_shards(), 2);
    pool.drain();
    let (tx, _rx) = mpsc::channel();
    let refused = pool.submit(Pending {
        kind: JobKind::Infer,
        model: None,
        seed: 1,
        enqueued: Instant::now(),
        deadline: None,
        reply: tx,
    });
    assert_eq!(refused.unwrap_err(), Admission::ShuttingDown);
    assert_eq!(pool.metrics.dropped_shutdown.load(Ordering::Relaxed), 1);
    // idempotent
    pool.drain();
}

#[test]
fn stats_per_model_counters_match_scripted_trace() {
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        one_worker(),
        1,
    )
    .unwrap();
    let addr = srv.addr;
    let client = thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        // scripted trace: 3 default (TinyCNN), 2 AlexNet-test, 1
        // SqueezeNet-test — closed loop, so the counts are exact
        for seed in 0..3 {
            c.infer(seed).unwrap();
        }
        for seed in 0..2 {
            c.infer_model("alexnet-test", seed).unwrap();
        }
        c.infer_model("squeezenet_test", 0).unwrap();
        c.stats().unwrap()
    });
    serve_clients(&mut srv, std::slice::from_ref(&client), 60);
    let stats = client.join().unwrap();
    assert!(stats.starts_with("STATS requests=6 responses=6"), "{stats}");
    assert!(stats.contains("TinyCNN: req=3"), "{stats}");
    assert!(stats.contains("AlexNet-test: req=2"), "{stats}");
    assert!(stats.contains("SqueezeNet-test: req=1"), "{stats}");
    assert!(stats.contains("shards=[s0: req=6"), "{stats}");
    assert!(stats.contains("util_pct="), "{stats}");
    assert_eq!(srv.metrics.spills.load(Ordering::Relaxed), 0);
    srv.shutdown();
}

#[test]
fn explain_and_util_pct_ride_the_wire_together() {
    // EXPLAIN (predicted per-step utilization) and STATS util_pct
    // (measured) are the two halves of the Fig.-19-style story; both
    // must round-trip the line protocol on a sharded server
    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        EngineOptions { num_threads: 2, ..Default::default() },
        2,
    )
    .unwrap();
    let addr = srv.addr;
    let client = thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        let rows = c.explain("squeezenet-test").unwrap();
        assert!(rows[0].starts_with("PLAN SqueezeNet-test steps="), "{}", rows[0]);
        assert!(rows[0].ends_with("threads=2"), "{}", rows[0]);
        let steps_tok = rows[0].split("steps=").nth(1).unwrap();
        let steps: usize =
            steps_tok.split_whitespace().next().unwrap().parse().unwrap();
        assert_eq!(rows.len() - 1, steps, "one STEP row per program step");
        for row in &rows[1..] {
            assert!(row.contains("hw_util=") && row.contains("sw_util="), "{row}");
            assert!(row.contains("split=serial") || row.contains("split=rows"), "{row}");
        }
        // traffic, then the measured gauge appears in STATS
        for seed in 0..4 {
            c.infer_model("squeezenet-test", seed).unwrap();
        }
        let stats = c.stats().unwrap();
        assert!(stats.contains("SqueezeNet-test: req=4"), "{stats}");
        let util = neuromax::coordinator::metrics::parse_model_gauge(
            &stats,
            "SqueezeNet-test",
            "util_pct",
        );
        assert!(util.is_some(), "util_pct must parse from: {stats}");
        stats
    });
    serve_clients(&mut srv, std::slice::from_ref(&client), 60);
    client.join().unwrap();
    srv.shutdown();
}

#[test]
fn hotspot_traffic_replicates_the_hot_model_and_drains_cleanly() {
    // Adaptive pool with an aggressive replication policy: sustained
    // closed-loop traffic against one model must grow it a replica
    // (observable in the STATS `replicas=[...]` / `replica_grows=`
    // fields), and shutdown must still drain cleanly with the
    // controller thread running.
    let opts = PoolOptions {
        spill_threshold: Some(1),
        replication: Some(ReplicationPolicy {
            tick: Duration::from_millis(10),
            window: 2,
            grow_util_pct: 1.0,
            grow_min_arrivals: 2,
            // never shrink mid-test: the grow assertions stay race-free
            cold_ticks: u32::MAX,
            shrink_util_pct: 0.0,
            ..Default::default()
        }),
        ..Default::default()
    };
    let mut srv = Server::start_sharded_with_opts(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1), ..Default::default() },
        one_worker(),
        2,
        opts,
    )
    .unwrap();
    let addr = srv.addr;
    let metrics = srv.metrics.clone();
    // hotspot trace: every request hits the default model, from enough
    // connections that its home queue stays warm across ticks
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let metrics = metrics.clone();
            thread::spawn(move || {
                let mut cl = Client::connect(addr).unwrap();
                let deadline = Instant::now() + Duration::from_secs(30);
                let mut seed = (c * 100_000) as u64;
                // closed loop until the controller visibly grew a replica
                // (plus a fixed floor so counters are never trivial)
                while (seed % 100_000 < 40
                    || metrics.replica_grows.load(Ordering::Relaxed) == 0)
                    && Instant::now() < deadline
                {
                    let (class, _) = cl.infer(seed).unwrap();
                    assert!(class < 10);
                    seed += 1;
                }
                cl.stats().unwrap()
            })
        })
        .collect();
    serve_clients(&mut srv, &clients, 60);
    let stats = clients.into_iter().map(|c| c.join().unwrap()).next_back().unwrap();
    assert!(
        metrics.replica_grows.load(Ordering::Relaxed) >= 1,
        "hotspot traffic never triggered replication: {stats}"
    );
    assert!(stats.contains("replica_grows="), "{stats}");
    assert!(
        stats.contains("replicas=[TinyCNN: s"),
        "the replica set must ride the STATS wire line: {stats}"
    );
    // both shards executed the hot model once the replica went live
    srv.shutdown();
    assert!(
        metrics.responses.load(Ordering::Relaxed) >= 160,
        "every closed-loop request must be answered: {}",
        metrics.summary()
    );
}
