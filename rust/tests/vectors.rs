//! Cross-language bit-exactness: the rust LNS datapath and dataflow
//! executor against the python-generated oracle vectors (`tv_*.txt` from
//! `python/compile/aot.py`). These pin the two independent
//! implementations of eq. 3-8 together.

mod common;

use neuromax::dataflow::exec;
use neuromax::lns::{logquant, mult, tables};
use neuromax::tensor::Tensor3;

#[test]
fn quantizer_matches_python() {
    let Some(dir) = common::artifacts_dir() else { return };
    let text = common::read(&dir, "tv_quant.txt");
    let mut checked = 0;
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let val: f64 = it.next().unwrap().parse().unwrap();
        let code: i32 = it.next().unwrap().parse().unwrap();
        let sign: i32 = it.next().unwrap().parse().unwrap();
        let (rc, rs) = logquant::quantize(val as f32);
        assert_eq!((rc, rs), (code, sign), "value {val}");
        checked += 1;
    }
    assert!(checked > 200, "only {checked} vectors");
}

#[test]
fn requant_matches_python() {
    let Some(dir) = common::artifacts_dir() else { return };
    let text = common::read(&dir, "tv_requant.txt");
    for line in text.lines() {
        let mut it = line.split_whitespace();
        let psum: i64 = it.next().unwrap().parse().unwrap();
        let code: i32 = it.next().unwrap().parse().unwrap();
        assert_eq!(tables::requant_act(psum as i32), code, "psum {psum}");
    }
}

#[test]
fn thread_mult_matches_python() {
    let Some(dir) = common::artifacts_dir() else { return };
    let text = common::read(&dir, "tv_mult.txt");
    for line in text.lines() {
        let v: Vec<i64> = line.split_whitespace().map(|x| x.parse().unwrap()).collect();
        let got = mult::thread_mult(v[0] as i32, v[1] as i32, v[2] as i32);
        assert_eq!(got as i64, v[3], "codes {} {} {}", v[0], v[1], v[2]);
    }
}

fn check_conv(file: &str) {
    let Some(dir) = common::artifacts_dir() else { return };
    let c = common::conv_case(&dir, file);
    let out = exec::conv2d(&c.a, &c.wc, &c.ws, c.stride);
    assert_eq!(out.data, c.out, "{file}: psums differ from python oracle");
    if let Some(req) = &c.req {
        let got = exec::requant(&out);
        assert_eq!(&got.data, req, "{file}: requant codes differ");
    }
}

#[test]
fn conv3x3_s1_matches_python() {
    check_conv("tv_conv3x3_s1.txt");
    check_conv("tv_conv3x3_s1b.txt");
}

#[test]
fn conv3x3_s2_matches_python() {
    check_conv("tv_conv3x3_s2.txt");
}

#[test]
fn conv5x5_matches_python() {
    check_conv("tv_conv5x5.txt");
}

#[test]
fn conv4x4_matches_python() {
    check_conv("tv_conv4x4.txt");
}

#[test]
fn conv7x7_s2_matches_python() {
    check_conv("tv_conv7x7.txt");
}

#[test]
fn conv1x1_matches_python() {
    let Some(dir) = common::artifacts_dir() else { return };
    let text = common::read(&dir, "tv_conv1x1.txt");
    let kv = common::kv_lines(&text);
    let to_i32 = |v: &Vec<i64>| v.iter().map(|&x| x as i32).collect::<Vec<_>>();
    let (p, c) = (kv["shape_a"][0] as usize, kv["shape_a"][1] as usize);
    let k = kv["shape_w"][0] as usize;
    let a = Tensor3::from_vec(p, 1, c, to_i32(&kv["a"]));
    let wc = neuromax::tensor::Tensor4::from_vec(k, 1, 1, c, to_i32(&kv["wc"]));
    let ws = neuromax::tensor::Tensor4::from_vec(k, 1, 1, c, to_i32(&kv["ws"]));
    let out = exec::pointwise(&a, &wc, &ws, 1);
    assert_eq!(out.data, to_i32(&kv["out"]));
}

#[test]
fn depthwise_matches_python() {
    let Some(dir) = common::artifacts_dir() else { return };
    let text = common::read(&dir, "tv_dw3x3.txt");
    let kv = common::kv_lines(&text);
    let to_i32 = |v: &Vec<i64>| v.iter().map(|&x| x as i32).collect::<Vec<_>>();
    let sa = &kv["shape_a"];
    let a = Tensor3::from_vec(sa[0] as usize, sa[1] as usize, sa[2] as usize, to_i32(&kv["a"]));
    let c = sa[2] as usize;
    let wc = neuromax::tensor::Tensor4::from_vec(c, 3, 3, 1, to_i32(&kv["wc"]));
    let ws = neuromax::tensor::Tensor4::from_vec(c, 3, 3, 1, to_i32(&kv["ws"]));
    let out = exec::depthwise(&a, &wc, &ws, 1);
    assert_eq!(out.data, to_i32(&kv["out"]));
}

#[test]
fn tinycnn_forward_matches_python() {
    let Some(dir) = common::artifacts_dir() else { return };
    let text = common::read(&dir, "tv_tinycnn.txt");
    // parse "tensor <name> <dims...>" + flat line pairs
    let mut tensors: Vec<(String, Vec<usize>, Vec<i32>)> = Vec::new();
    let mut lines = text.lines();
    while let Some(h) = lines.next() {
        let mut it = h.split_whitespace();
        assert_eq!(it.next(), Some("tensor"));
        let name = it.next().unwrap().to_string();
        let dims: Vec<usize> = it.map(|d| d.parse().unwrap()).collect();
        let data: Vec<i32> = lines
            .next()
            .unwrap()
            .split_whitespace()
            .map(|v| v.parse().unwrap())
            .collect();
        tensors.push((name, dims, data));
    }
    let logits_py = tensors.pop().unwrap().2;
    let a = Tensor3::from_vec(16, 16, 4, tensors[0].2.clone());
    let shapes = neuromax::models::tinycnn::TinyCnnWeights::shapes();
    let mut codes = Vec::new();
    let mut signs = Vec::new();
    for (i, (k, kh, kw, c)) in shapes.iter().enumerate() {
        codes.push(neuromax::tensor::Tensor4::from_vec(
            *k, *kh, *kw, *c, tensors[1 + 2 * i].2.clone()));
        signs.push(neuromax::tensor::Tensor4::from_vec(
            *k, *kh, *kw, *c, tensors[2 + 2 * i].2.clone()));
    }
    let w = neuromax::models::tinycnn::TinyCnnWeights { codes, signs };
    let logits = neuromax::runtime::verify::tinycnn_forward_sim(&a, &w);
    assert_eq!(logits, logits_py, "full-network forward differs from python");
}
