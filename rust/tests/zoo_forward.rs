//! Whole-zoo graph-executor equivalence: every model (scaled test
//! profiles) must run end-to-end through both the reference forward
//! (`dataflow::exec` numerics) and the LUT-fused engine forward
//! (`dataflow::engine` numerics) with **bit-identical** outputs, at 1
//! and 4 worker threads, single-shot and batched — plus the serving
//! stack on top: per-request model selection over the TCP protocol.

use std::time::{Duration, Instant};

use neuromax::coordinator::batcher::BatchPolicy;
use neuromax::coordinator::pipeline::{Backend, InferenceEngine};
use neuromax::coordinator::server::{Client, Server};
use neuromax::dataflow::engine::{Engine, EngineOptions};
use neuromax::dataflow::forward::{
    forward_engine_batch, forward_engine_planned, forward_ref_planned, ForwardPlan,
};
use neuromax::models::runner::{random_input_for, NetWeights};
use neuromax::models::workload;

const THREADS: [usize; 2] = [1, 4];

#[test]
fn every_zoo_model_engine_equals_reference() {
    for name in workload::ZOO_NAMES {
        let net = workload::test_profile(name).unwrap();
        let plan = ForwardPlan::infer(&net).unwrap_or_else(|e| panic!("{name}: {e}"));
        for seed in 0..2u64 {
            let w = NetWeights::random(&net, seed.wrapping_mul(7919) ^ 0xBEEF);
            let fused = w.fuse();
            let x = random_input_for(&net, seed + 1);
            let want = forward_ref_planned(&net, &plan, &w, &x);
            assert!(!want.data.is_empty(), "{name}: empty output");
            for threads in THREADS {
                // forced: row-parallel path engages even on tiny layers
                let eng = Engine::with_threads_forced(threads);
                let got = forward_engine_planned(&eng, &net, &plan, &fused, &x);
                assert_eq!(
                    got, want,
                    "{name}: engine != reference at seed={seed} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn every_zoo_model_batch_matches_singles() {
    for name in workload::ZOO_NAMES {
        let net = workload::test_profile(name).unwrap();
        let plan = ForwardPlan::infer(&net).unwrap();
        let w = NetWeights::random(&net, 0xC0DE);
        let fused = w.fuse();
        let inputs: Vec<_> = (0..5).map(|i| random_input_for(&net, i)).collect();
        let eng = Engine::with_threads(4);
        let batch = forward_engine_batch(&eng, &net, &plan, &fused, &inputs);
        assert_eq!(batch.len(), inputs.len(), "{name}");
        for (x, got) in inputs.iter().zip(&batch) {
            let single = forward_engine_planned(&eng, &net, &plan, &fused, x);
            assert_eq!(got, &single, "{name}: batch element != single-shot");
        }
    }
}

#[test]
fn pipeline_serves_every_zoo_model_deterministically() {
    for name in workload::ZOO_NAMES {
        let net = workload::test_profile(name).unwrap();
        let mut e =
            InferenceEngine::for_network(net, Backend::Sim, 7, EngineOptions::default())
                .unwrap();
        let input = e.input(3);
        let a = e.infer(&input).unwrap();
        let b = e.infer(&input).unwrap();
        assert_eq!(a.logits, b.logits, "{name}");
        assert!(a.accel_cycles > 0, "{name}");
        // the pipeline's logits equal the raw generic reference forward
        let reference = neuromax::runtime::verify::forward_ref(&e.model, &e.weights, &input);
        assert_eq!(a.logits, reference, "{name}: pipeline != reference");
    }
}

#[test]
fn server_roundtrip_with_per_request_model() {
    let mut srv = Server::start(
        "127.0.0.1:0",
        Backend::Sim,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
    )
    .unwrap();
    let addr = srv.addr;
    let client = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        // INFER <model> <seed> round-trips for several zoo test profiles
        for model in ["tinycnn", "alexnet-test", "squeezenet_test", "mobilenet_v1-test"] {
            let (class, _us) = c.infer_model(model, 11).unwrap();
            let (class2, _us) = c.infer_model(model, 11).unwrap();
            assert_eq!(class, class2, "{model}: same seed must repeat");
        }
        // default-model requests still interleave fine
        let (class, _) = c.infer(5).unwrap();
        assert!(class < 10);
    });
    srv.serve_until(Some(Instant::now() + Duration::from_secs(8))).unwrap();
    client.join().unwrap();
    srv.shutdown();
}
