//! Whole-zoo graph-executor equivalence: every model (scaled test
//! profiles) must run end-to-end through both the reference forward
//! (`dataflow::exec` numerics) and the LUT-fused engine forward
//! (`dataflow::engine` numerics) with **bit-identical** outputs, at 1
//! and 4 worker threads, single-shot and batched — plus the serving
//! stack on top: per-request model selection over the TCP protocol.

use std::time::{Duration, Instant};

use neuromax::coordinator::batcher::BatchPolicy;
use neuromax::coordinator::pipeline::{Backend, InferenceEngine};
use neuromax::coordinator::server::{Client, Server};
use neuromax::coordinator::shard::WEIGHT_SEED;
use neuromax::dataflow::engine::{Engine, EngineOptions};
use neuromax::dataflow::forward::{
    forward_engine_batch, forward_engine_planned, forward_ref_planned, ForwardPlan,
};
use neuromax::models::runner::{random_input_for, NetWeights};
use neuromax::models::workload;

const THREADS: [usize; 2] = [1, 4];

#[test]
fn every_zoo_model_engine_equals_reference() {
    for name in workload::ZOO_NAMES {
        let net = workload::test_profile(name).unwrap();
        let plan = ForwardPlan::infer(&net).unwrap_or_else(|e| panic!("{name}: {e}"));
        for seed in 0..2u64 {
            let w = NetWeights::random(&net, seed.wrapping_mul(7919) ^ 0xBEEF);
            let fused = w.fuse();
            let x = random_input_for(&net, seed + 1);
            let want = forward_ref_planned(&net, &plan, &w, &x);
            assert!(!want.data.is_empty(), "{name}: empty output");
            for threads in THREADS {
                // forced: row-parallel path engages even on tiny layers
                let eng = Engine::with_threads_forced(threads);
                let got = forward_engine_planned(&eng, &net, &plan, &fused, &x);
                assert_eq!(
                    got, want,
                    "{name}: engine != reference at seed={seed} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn every_zoo_model_batch_matches_singles() {
    for name in workload::ZOO_NAMES {
        let net = workload::test_profile(name).unwrap();
        let plan = ForwardPlan::infer(&net).unwrap();
        let w = NetWeights::random(&net, 0xC0DE);
        let fused = w.fuse();
        let inputs: Vec<_> = (0..5).map(|i| random_input_for(&net, i)).collect();
        let eng = Engine::with_threads(4);
        let batch = forward_engine_batch(&eng, &net, &plan, &fused, &inputs);
        assert_eq!(batch.len(), inputs.len(), "{name}");
        for (x, got) in inputs.iter().zip(&batch) {
            let single = forward_engine_planned(&eng, &net, &plan, &fused, x);
            assert_eq!(got, &single, "{name}: batch element != single-shot");
        }
    }
}

#[test]
fn pipeline_serves_every_zoo_model_deterministically() {
    for name in workload::ZOO_NAMES {
        let net = workload::test_profile(name).unwrap();
        let mut e =
            InferenceEngine::for_network(net, Backend::Sim, 7, EngineOptions::default())
                .unwrap();
        let input = e.input(3);
        let a = e.infer(&input).unwrap();
        let b = e.infer(&input).unwrap();
        assert_eq!(a.logits, b.logits, "{name}");
        assert!(a.accel_cycles > 0, "{name}");
        // the pipeline's logits equal the raw generic reference forward
        let reference = neuromax::runtime::verify::forward_ref(&e.model, &e.weights, &input);
        assert_eq!(a.logits, reference, "{name}: pipeline != reference");
    }
}

#[test]
fn server_roundtrip_with_per_request_model() {
    let mut srv = Server::start(
        "127.0.0.1:0",
        Backend::Sim,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
    )
    .unwrap();
    let addr = srv.addr;
    let client = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        // INFER <model> <seed> round-trips for several zoo test profiles
        for model in ["tinycnn", "alexnet-test", "squeezenet_test", "mobilenet_v1-test"] {
            let (class, _us) = c.infer_model(model, 11).unwrap();
            let (class2, _us) = c.infer_model(model, 11).unwrap();
            assert_eq!(class, class2, "{model}: same seed must repeat");
        }
        // default-model requests still interleave fine
        let (class, _) = c.infer(5).unwrap();
        assert!(class < 10);
    });
    srv.serve_until(Some(Instant::now() + Duration::from_secs(8))).unwrap();
    client.join().unwrap();
    srv.shutdown();
}

#[test]
fn sharded_server_bit_exact_under_mixed_model_traffic() {
    // Acceptance pin for the sharded pool: a shards=4 server answering
    // interleaved multi-model traffic must classify exactly like a
    // locally-built engine (same weight seed) — shard placement, model
    // grouping and spills may change scheduling, never numerics.
    const MODELS: [&str; 6] = [
        "tinycnn",
        "alexnet-test",
        "vgg16-test",
        "resnet34-test",
        "mobilenet_v1-test",
        "squeezenet-test",
    ];
    const SEEDS: [u64; 2] = [11, 23];
    let mut expected = std::collections::HashMap::new();
    for name in MODELS {
        let mut e =
            InferenceEngine::for_model(name, Backend::Sim, WEIGHT_SEED, EngineOptions::default())
                .unwrap();
        for seed in SEEDS {
            let input = e.input(seed);
            expected.insert((name, seed), e.infer(&input).unwrap().class);
        }
    }
    let expected = std::sync::Arc::new(expected);

    let mut srv = Server::start_sharded(
        "127.0.0.1:0",
        "tinycnn",
        Backend::Sim,
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), ..Default::default() },
        EngineOptions { num_threads: 2, ..Default::default() },
        4,
    )
    .unwrap();
    assert_eq!(srv.shards(), 4);
    let addr = srv.addr;
    let clients: Vec<_> = (0..3)
        .map(|t| {
            let expected = expected.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                // each client walks the zoo in a different order so the
                // dynamic batches mix models differently per shard
                for step in 0..MODELS.len() * SEEDS.len() {
                    let idx = (step + t * 5) % (MODELS.len() * SEEDS.len());
                    let (model, seed) =
                        (MODELS[idx % MODELS.len()], SEEDS[idx / MODELS.len()]);
                    let (class, _us) = c.infer_model(model, seed).unwrap();
                    assert_eq!(
                        class, expected[&(model, seed)],
                        "{model} seed={seed}: sharded server disagrees with reference"
                    );
                }
            })
        })
        .collect();
    srv.serve_while(Duration::from_secs(120), || clients.iter().all(|c| c.is_finished()))
        .unwrap();
    for c in clients {
        c.join().unwrap();
    }
    assert_eq!(
        srv.metrics.responses.load(std::sync::atomic::Ordering::Relaxed),
        3 * (MODELS.len() as u64 * SEEDS.len() as u64)
    );
    srv.shutdown();
}
