//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the (small) subset of anyhow's API that neuromax uses, with
//! the same names and semantics:
//!
//! * [`Error`]: an opaque, `Send + Sync` error value built from any
//!   `std::error::Error` or from a message; context frames prepend
//!   `"context: cause"` exactly like anyhow's `{:#}` rendering.
//! * [`Result<T>`]: `std::result::Result<T, Error>` alias.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` (that is what makes the blanket `From` impl
//! coherent). If the real anyhow ever becomes available, deleting this
//! directory and switching the path dependency to a version is a drop-in
//! change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: rendered message plus flattened source chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prepend a context frame (anyhow renders chains as `ctx: cause`).
    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// Context extension for `Result` and `Option` (mirrors anyhow).
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a displayable value, or
/// format args (same three arms as the real crate).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from format args.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("not an int")?;
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn conversion_and_context() {
        assert_eq!(parse("7").unwrap(), 7);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("not an int:"), "{e}");
        let e = parse("-3").unwrap_err();
        assert_eq!(e.to_string(), "negative: -3");
    }

    #[test]
    fn option_context_and_bail() {
        fn f(v: Option<u32>) -> Result<u32> {
            let v = v.with_context(|| format!("missing {}", "slot"))?;
            if v == 0 {
                bail!("zero");
            }
            Ok(v)
        }
        assert_eq!(f(Some(2)).unwrap(), 2);
        assert_eq!(f(None).unwrap_err().to_string(), "missing slot");
        assert_eq!(f(Some(0)).unwrap_err().to_string(), "zero");
    }

    #[test]
    fn source_chain_is_flattened() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "inner");
        let e = Error::from(io).wrap("outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(format!("{e:?}"), "outer: inner");
    }

    #[test]
    fn threads_can_carry_errors() {
        let h = std::thread::spawn(|| -> Result<()> { bail!("from thread") });
        assert!(h.join().unwrap().is_err());
    }
}
